"""ColdStore: the compacted history tier below the hot ring (DESIGN.md §7.8).

The ring-buffer serving engine (DESIGN.md §7.3) holds a bounded recent
horizon of the time-first permutation; a forward slide EVICTS the
positions leaving ``[lo, lo+C)`` and, before this module, anything evicted
was simply gone — a query window older than the ring's low watermark was
an unguarded edge case.  Following Khurana & Deshpande's DeltaGraph
(delta-encoded historical snapshots) and the in-memory compact temporal
structures it inspired, the cold store keeps that history as **chunked,
delta-encoded time-first segments**:

  * a chunk is a FIXED SPAN of evicted time-first positions
    (``chunk_slots`` of them), sealed with a ``[t_lo, t_hi)`` start-time
    fence and registered in a host-side chunk directory;
  * inside a chunk, ``t_start`` is ascending by the time-first invariant,
    so it stores as a base + non-negative deltas (uint16 when they fit),
    durations (``t_end - t_start``) likewise, and an all-ones weight
    column stores as nothing at all;
  * compaction happens strictly OFF the fused dispatch path: the serving
    engine notes the evicted position range AFTER the donated step
    returns, and the store seals chunks host-side from its own host
    mirrors of the graph arrays (one device->host transfer per graph,
    ever) — the steady-state advance stays one fused dispatch with zero
    extra retraces.

Queries below the hot horizon then STITCH: :meth:`ColdStore.ring_stitch`
rebuilds the exact index ring view (slot order included) for any window
whose positions are covered, decoding the sealed chunks and gathering the
unsealed pending tail / hot suffix from the host mirrors, so a cold-tier
solve is row-bit-identical to a cold full-history index solve under the
same plan.  The tier decision itself (hot / cold / split) lives on the
:class:`~repro.engine.plan.AccessPlan` signature — see ``plan_query``.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.temporal_graph import TemporalGraph
from repro.core.tger import TGERIndex, window_positions_host

_RAW_BYTES_PER_EDGE = 20  # src,dst,t_start,t_end int32 + weight f32


def _pack_unsigned(a: np.ndarray) -> np.ndarray:
    """Smallest unsigned dtype that holds the (non-negative) values."""
    if a.size and int(a.max()) >= 1 << 16:
        return a.astype(np.uint32)
    return a.astype(np.uint16)


@dataclasses.dataclass(frozen=True)
class ColdChunk:
    """One sealed span of evicted time-first positions ``[pos_lo, pos_hi)``
    with its ``[t_lo, t_hi)`` start-time fence (``t_hi`` is the start time
    of the first position AFTER the chunk — fences tile the timeline, so
    the directory answers "which chunks can hold starts in this window"
    without touching payloads)."""

    pos_lo: int
    pos_hi: int
    t_lo: int
    t_hi: int
    src: np.ndarray        # i32[n]
    dst: np.ndarray        # i32[n]
    dt_start: np.ndarray   # u16/u32[n-1] deltas of the ascending t_start
    dur: np.ndarray        # u16/u32[n]  t_end - t_start
    weight: Optional[np.ndarray]  # f32[n], or None when the column is all-ones

    @property
    def n(self) -> int:
        return self.pos_hi - self.pos_lo

    @property
    def nbytes(self) -> int:
        w = 0 if self.weight is None else self.weight.nbytes
        return (self.src.nbytes + self.dst.nbytes + self.dt_start.nbytes
                + self.dur.nbytes + w)

    def decode(self) -> Tuple[np.ndarray, ...]:
        """Reconstruct the raw ``(src, dst, t_start, t_end, weight)``
        columns, bit-exact vs the arrays the chunk was sealed from."""
        ts = np.empty(self.n, np.int64)
        ts[0] = self.t_lo
        if self.n > 1:
            np.cumsum(self.dt_start, dtype=np.int64, out=ts[1:])
            ts[1:] += self.t_lo
        te = ts + self.dur.astype(np.int64)
        w = (np.ones(self.n, np.float32) if self.weight is None
             else self.weight)
        return (self.src, self.dst, ts.astype(np.int32),
                te.astype(np.int32), w)


class ColdStore:
    """Host-side compacted history for one ``(graph, TGER)`` pair.

    The store's coverage is the position prefix ``[0, watermark)`` of the
    global time-first permutation: :meth:`note_eviction` (called by the
    serving engine whenever the ring's low watermark advances) extends it
    and seals every completed ``chunk_slots`` span into a
    :class:`ColdChunk`; the first note backfills from position 0, so the
    pre-serving history enters as one compaction and every window below
    the hot horizon is answerable.  The uncompacted tail
    ``[sealed, watermark)`` (less than one chunk) serves straight from the
    host mirrors until its chunk completes.

    ``spill_dir`` moves sealed chunk payloads out of RAM: each chunk's
    delta-encoded columns are written to one file and rebound as read-only
    ``np.memmap`` views, decoded through exactly the same code path
    (bit-identical stitches — the memmap is just a lazier ndarray).  The
    chunk directory (fences and position spans) stays in memory, so tier
    classification and ``chunks_for`` lookups never touch disk; only a
    cold-tier decode pages payload bytes in.
    """

    def __init__(self, g: TemporalGraph, tger: TGERIndex, *,
                 chunk_slots: int = 1024,
                 spill_dir: Optional[str] = None):
        if tger is None:
            raise ValueError("ColdStore requires a TGER index (the time-"
                             "first permutation is the compaction domain)")
        if int(chunk_slots) < 1:
            raise ValueError(f"chunk_slots must be >= 1, got {chunk_slots}")
        self.graph = g
        self.tger = tger
        self.chunk_slots = int(chunk_slots)
        self.spill_dir = None if spill_dir is None else str(spill_dir)
        if self.spill_dir is not None:
            os.makedirs(self.spill_dir, exist_ok=True)
        self.n_positions = int(g.n_edges)
        self._covered = 0
        self._sealed = 0
        self._chunks: List[ColdChunk] = []
        self._host: Optional[Dict[str, np.ndarray]] = None
        self._decoded: Dict[int, Tuple[np.ndarray, ...]] = {}
        self.n_compactions = 0
        self.n_spilled = 0

    # -- host mirrors --------------------------------------------------------

    def _mirrors(self) -> Dict[str, np.ndarray]:
        """Host copies of the graph's edge columns and the time-first
        permutation — materialized lazily, once per store (compaction and
        stitching are pure host work after this)."""
        if self._host is None:
            g = self.graph
            self._host = dict(
                src=np.asarray(g.src), dst=np.asarray(g.dst),
                t_start=np.asarray(g.t_start), t_end=np.asarray(g.t_end),
                weight=np.asarray(g.weight),
                perm=np.asarray(self.tger.perm_by_start).astype(np.int64),
                start_sorted=np.asarray(self.tger.start_sorted),
            )
        return self._host

    # -- coverage / classification ------------------------------------------

    @property
    def watermark(self) -> int:
        """Positions ``[0, watermark)`` are cold (compacted or pending)."""
        return self._covered

    @property
    def chunks(self) -> Tuple[ColdChunk, ...]:
        return tuple(self._chunks)

    @property
    def n_chunks(self) -> int:
        return len(self._chunks)

    @property
    def pending_slots(self) -> int:
        """Covered positions not yet sealed into a chunk (< chunk_slots)."""
        return self._covered - self._sealed

    def positions(self, window) -> Tuple[int, int]:
        """The window's ``[lo, hi)`` range over the time-first positions."""
        return window_positions_host(self.tger, window)

    def classify(self, window, hot_lo: Optional[int] = None) -> str:
        """Tier of a window against the hot horizon: ``"hot"`` (at or above
        ``hot_lo``), ``"cold"`` (entirely below) or ``"split"``
        (straddling).  ``hot_lo`` defaults to the store's watermark; the
        serving engine passes its carried ring's own low watermark instead,
        so a forward-sliding chain stays hot even when another chain pushed
        the global watermark past it."""
        lo, hi = self.positions(window)
        hot_lo = self._covered if hot_lo is None else int(hot_lo)
        if lo >= hot_lo:
            return "hot"
        if hi <= hot_lo:
            return "cold"
        return "split"

    # -- compaction ----------------------------------------------------------

    def note_eviction(self, lo_new) -> int:
        """Extend coverage to the ring's new low watermark ``lo_new``;
        seal every completed chunk span.  Monotone and idempotent —
        re-noting an already-covered watermark is free.  Returns the number
        of newly covered positions."""
        lo_new = min(max(int(lo_new), 0), self.n_positions)
        if lo_new <= self._covered:
            return 0
        added = lo_new - self._covered
        self._covered = lo_new
        while self._covered - self._sealed >= self.chunk_slots:
            self._seal(self._sealed, self._sealed + self.chunk_slots)
        self.n_compactions += 1
        return added

    def _seal(self, a: int, b: int) -> None:
        h = self._mirrors()
        eids = h["perm"][a:b]
        ts = h["t_start"][eids].astype(np.int64)
        dur = h["t_end"][eids].astype(np.int64) - ts
        w = h["weight"][eids]
        ss = h["start_sorted"]
        t_hi = (int(ss[b]) if b < ss.shape[0]
                else int(np.iinfo(np.int32).max))
        chunk = ColdChunk(
            pos_lo=a, pos_hi=b, t_lo=int(ts[0]), t_hi=t_hi,
            src=np.ascontiguousarray(h["src"][eids]),
            dst=np.ascontiguousarray(h["dst"][eids]),
            dt_start=_pack_unsigned(np.diff(ts)),
            dur=_pack_unsigned(dur),
            weight=(None if np.all(w == np.float32(1.0))
                    else np.ascontiguousarray(w)),
        )
        if self.spill_dir is not None:
            chunk = self._spill(chunk)
        self._chunks.append(chunk)
        self._sealed = b

    def _spill(self, chunk: ColdChunk) -> ColdChunk:
        """Write the sealed payload columns to ONE file under ``spill_dir``
        and rebind them as read-only ``np.memmap`` views — an ndarray
        subclass, so :meth:`ColdChunk.decode` and every gather path read
        through it unchanged while the OS pages the bytes in and out on
        demand (the directory fences and pos/t metadata stay in RAM, so
        ``chunks_for`` never touches disk).  Zero-size columns (a 1-slot
        chunk's empty delta column) stay in memory: mmap cannot map an
        empty span."""
        cols = dict(src=chunk.src, dst=chunk.dst,
                    dt_start=chunk.dt_start, dur=chunk.dur)
        if chunk.weight is not None:
            cols["weight"] = chunk.weight
        path = os.path.join(
            self.spill_dir,
            f"chunk_{chunk.pos_lo:012d}_{chunk.pos_hi:012d}.bin")
        offsets: Dict[str, int] = {}
        with open(path, "wb") as f:
            for name, a in cols.items():
                offsets[name] = f.tell()
                f.write(np.ascontiguousarray(a).tobytes())
        mapped: Dict[str, np.ndarray] = {}
        for name, a in cols.items():
            mapped[name] = (a if a.size == 0 else np.memmap(
                path, dtype=a.dtype, mode="r", offset=offsets[name],
                shape=a.shape))
        self.n_spilled += 1
        return dataclasses.replace(chunk, **mapped)

    # -- stitching -----------------------------------------------------------

    def chunks_for(self, window) -> List[ColdChunk]:
        """The sealed chunks whose start-time fence overlaps the window —
        the directory lookup (fences only, no payloads touched)."""
        w0, w1 = int(window[0]), int(window[1])
        return [c for c in self._chunks if c.t_lo <= w1 and w0 < c.t_hi]

    def _decode(self, ci: int) -> Tuple[np.ndarray, ...]:
        dec = self._decoded.get(ci)
        if dec is None:
            dec = self._chunks[ci].decode()
            if len(self._decoded) >= 8:     # bounded decode cache
                self._decoded.pop(next(iter(self._decoded)))
            self._decoded[ci] = dec
        return dec

    def gather_positions(self, pos: np.ndarray) -> Tuple[np.ndarray, ...]:
        """Edge columns for arbitrary time-first positions: sealed spans
        decode from their chunks, everything else (the pending tail and
        the hot suffix of a split window) gathers from the host mirrors.
        Positions clamp to the last edge exactly like ``index_ring_view``
        does, so a stitched view's padding payload matches the device
        build bit-for-bit."""
        h = self._mirrors()
        pos = np.minimum(np.asarray(pos, np.int64), self.n_positions - 1)
        out = [np.empty(pos.shape, np.int32) for _ in range(4)]
        out.append(np.empty(pos.shape, np.float32))
        names = ("src", "dst", "t_start", "t_end", "weight")
        cold_sel = pos < self._sealed
        if not cold_sel.all():
            eids = h["perm"][pos[~cold_sel]]
            for o, nm in zip(out, names):
                o[~cold_sel] = h[nm][eids]
        if cold_sel.any():
            cpos = pos[cold_sel]
            cidx = cpos // self.chunk_slots
            filled = [o[cold_sel] for o in out]
            for ci in np.unique(cidx):
                dec = self._decode(int(ci))
                sel = cidx == ci
                local = cpos[sel] - self._chunks[int(ci)].pos_lo
                for f, col in zip(filled, dec):
                    f[sel] = col[local]
            for o, f in zip(out, filled):
                o[cold_sel] = f
        return tuple(out)

    def ring_stitch(self, window, capacity: int):
        """Host build of the index ring view over ``window`` — bit-identical
        (slot order and masked payload included) to
        ``index_ring_view(g, tger, lo, hi, capacity=capacity)``, with the
        cold span decoded from the compacted chunks instead of gathered on
        device.  Returns ``(fields, mask, lo, hi)``; raises when the window
        spans more positions than ``capacity`` holds."""
        lo, hi = self.positions(window)
        if hi - lo > capacity:
            raise ValueError(
                f"window {tuple(int(w) for w in window)} spans {hi - lo} "
                f"time-first positions but the plan's ring capacity is "
                f"{capacity}; replan (the cold tier rungs its capacity "
                f"from the window span)")
        s = np.arange(capacity, dtype=np.int64)
        pos = lo + (s - lo) % capacity
        fields = self.gather_positions(pos)
        return fields, pos < hi, lo, hi

    # -- stats ---------------------------------------------------------------

    @property
    def nbytes(self) -> int:
        return sum(c.nbytes for c in self._chunks)

    def stats(self) -> Dict[str, float]:
        raw = self._sealed * _RAW_BYTES_PER_EDGE
        return dict(
            watermark=self._covered,
            sealed_slots=self._sealed,
            pending_slots=self.pending_slots,
            n_chunks=len(self._chunks),
            chunk_slots=self.chunk_slots,
            compactions=self.n_compactions,
            nbytes=self.nbytes,
            raw_nbytes=raw,
            compaction_ratio=(raw / self.nbytes) if self.nbytes else 0.0,
            spilled_chunks=self.n_spilled,
        )


__all__ = ["ColdStore", "ColdChunk"]
