"""FixpointRunner — gather-once fixpoint execution (DESIGN.md §7).

Every fixpoint algorithm in this repo is "relax over the window-valid edge
set until the frontier empties".  The edge view, the per-edge window
validity, the endpoint selection and the layout eligibility are all
loop-INVARIANT (the query window is fixed for the whole run), yet the
pre-runner algorithms rebuilt the view inside the ``lax.while_loop`` body —
on index/hybrid plans that re-issues the binary search + budgeted gather
EVERY relaxation round, O(rounds × budget) access work instead of the
O(budget) the plan promised.  The runner hoists all of it:

  * the edge view is built exactly ONCE per query (``for_query`` /
    ``for_windows``), before the loop — the only gather in the program;
  * ``valid`` is the precomputed structural ∧ window validity mask —
    ``bool[E']`` for a single window, ``bool[W, E']`` for a batched sweep
    (the matrix ``edge_map_over_view_batched``'s ``per_window`` closure
    used to recompute every round);
  * endpoints (``from_v``/``to_v``) and the static layout-eligibility bit
    are resolved at construction;
  * ``run`` drives the ``lax.while_loop`` with the uniform
    rounds-capped / condition-holds loop shape, and ``step`` executes one
    relaxation round over the hoisted view with ``touched`` computed only
    on request (it costs an extra segment-sum most algorithms discard).

The runner works identically for single-window ([V] state) and batched
([Q, V] state) execution — the batched path is how ``*_batched`` variants
and the incremental sliding-window server share one union-window view.
Since the multi-tenant refactor the batched row axis carries a **source
axis vmapped alongside the window axis** (DESIGN.md §7.4): each row q of
a batched run owns its own ``(source, window)`` pair, so one gathered
view answers a whole (algorithm × source × window) query batch —
``sources=`` normalizes a scalar / [Q] vector onto the row axis and the
``seeded`` / ``source_frontier`` helpers build the per-row inits every
frontier algorithm starts from.  ``for_view`` wraps views the runner did
not build — in particular the server's ring-buffer views, advanced in
place across sweeps (DESIGN.md §7.3).  ``run(with_rounds=True)`` /
``run_with_metrics`` export the ``touched``-driven convergence record
(:class:`FixpointMetrics`) for serving observability.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.engine.backends import (
    combine_for_plan,
    combine_windows_for_plan,
    segment_combine,
)
from repro.engine.plan import AccessPlan


class FixpointMetrics(NamedTuple):
    """The ``touched``-driven convergence record of one fixpoint run
    (observability for serving: how much work did this query actually do).

    ``rounds`` counts loop-body executions — the final body execution is the
    one that makes no further change and empties the frontier, matching the
    round count of a host-side reference loop run to no-change.
    ``touched_total`` sums, over all rounds, the vertices that received at
    least one valid contribution (the runner's per-round ``touched`` mask).
    ``frontier_trace`` (opt-in: ``run_with_metrics(frontier_trace=True)``)
    is the i32[max_rounds] per-round frontier occupancy — entry r holds the
    touched-vertex count of round r, -1 past the executed rounds.  It is
    the regime evidence the frontier-rung ladder's handoff reads (DESIGN.md
    §7.9): the tail of a deep solve shows occupancy collapsing to a handful
    of vertices while the dense round keeps paying O(E').
    """

    rounds: jax.Array          # i32 scalar
    touched_total: jax.Array   # i32 scalar
    frontier_trace: Optional[jax.Array] = None   # i32[max_rounds] | None


class FixpointRunner:
    """Owns one query's hoisted edge view and every loop-invariant quantity.

    Construct via :meth:`for_query` (single window) or :meth:`for_windows`
    (batched multi-window sweep), or directly from a prebuilt view (the
    incremental server advances a view across sweeps and re-wraps it).
    Constructed inside a jitted function, everything here is traced exactly
    once, OUTSIDE the while-loop body.
    """

    def __init__(
        self,
        edges,                          # EdgeView (prebuilt)
        window=None,                    # (ta, tb) — single-window mode
        *,
        windows=None,                   # i32[Q, 2] — batched mode
        sources=None,                   # scalar | i32[Q] — batched row sources
        plan: AccessPlan,
        n_vertices: int,
        direction: str = "out",
        check_window: bool = True,
        max_rounds: int = 0,
    ):
        from repro.core.edgemap import _endpoints
        from repro.core.predicates import in_window

        if (window is None) == (windows is None):
            raise ValueError("pass exactly one of window= or windows=")
        self.edges = edges
        self.plan = plan
        self.n_vertices = int(n_vertices)
        self.direction = direction
        self.batched = windows is not None
        self.max_rounds = int(max_rounds) or self.n_vertices + 1
        self.from_v, self.to_v = _endpoints(edges, direction)
        # static: tiled kernels need the graph's native dst order
        self.use_layout = plan.method == "scan" and direction == "out"

        if self.batched:
            self.windows = jnp.asarray(windows, jnp.int32).reshape(-1, 2)
            self.window = None
            # the source axis rides the row axis: rows[q] = (sources[q],
            # windows[q]) — a scalar source broadcasts over every row (the
            # pre-multi-tenant single-tenant sweep), a [Q] vector gives each
            # row its own seed vertex (DESIGN.md §7.4).
            if sources is None:
                self.sources = None
            else:
                s = jnp.asarray(sources, jnp.int32)
                self.sources = jnp.broadcast_to(
                    s.reshape(-1) if s.ndim else s,
                    (self.windows.shape[0],))
            if check_window:
                self.valid = jax.vmap(
                    lambda w: edges.mask
                    & in_window(edges.t_start, edges.t_end, w[0], w[1])
                )(self.windows)                                  # [W, E']
            else:
                self.valid = jnp.broadcast_to(
                    edges.mask, (self.windows.shape[0],) + edges.mask.shape
                )
        else:
            ta = jnp.asarray(window[0], jnp.int32)
            tb = jnp.asarray(window[1], jnp.int32)
            self.window = (ta, tb)
            self.windows = None
            self.sources = None
            self.valid = (
                edges.mask & in_window(edges.t_start, edges.t_end, ta, tb)
                if check_window else edges.mask
            )                                                    # [E']

    # -- construction ------------------------------------------------------

    @classmethod
    def for_query(
        cls,
        g,
        tger,
        window,
        *,
        plan: Optional[AccessPlan] = None,
        direction: str = "out",
        check_window: bool = True,
        max_rounds: int = 0,
    ) -> "FixpointRunner":
        """Single-window runner: ONE plan-directed view build per query."""
        from repro.core.edgemap import ensure_plan, view_for_plan

        plan = ensure_plan(plan)
        edges = view_for_plan(g, tger, window, plan)
        return cls(
            edges, window, plan=plan, n_vertices=g.n_vertices,
            direction=direction, check_window=check_window,
            max_rounds=max_rounds,
        )

    @classmethod
    def for_windows(
        cls,
        g,
        tger,
        windows,
        *,
        sources=None,
        plan: Optional[AccessPlan] = None,
        direction: str = "out",
        check_window: bool = True,
        max_rounds: int = 0,
    ) -> "FixpointRunner":
        """Batched runner: ONE union-window view serves all Q rows."""
        from repro.core.edgemap import ensure_plan, union_window, view_for_plan

        plan = ensure_plan(plan)
        windows = jnp.asarray(windows, jnp.int32).reshape(-1, 2)
        edges = view_for_plan(g, tger, union_window(windows), plan)
        return cls(
            edges, windows=windows, sources=sources, plan=plan,
            n_vertices=g.n_vertices, direction=direction,
            check_window=check_window, max_rounds=max_rounds,
        )

    # -- per-row source seeding (the vmapped source axis, DESIGN.md §7.4) --

    def seeded(self, fill, value, dtype=jnp.int32) -> jax.Array:
        """[Q, V] init builder for the batched row axis: every entry is
        ``fill`` except position ``(q, sources[q])`` which holds ``value``
        (scalar or [Q], e.g. each row's window start).  This is the init
        every frontier relaxation starts from, with the source axis and the
        window axis varying together per row."""
        if not self.batched or self.sources is None:
            raise ValueError("seeded() needs batched mode with sources=")
        Q = self.windows.shape[0]
        rows = jnp.arange(Q, dtype=jnp.int32)
        base = jnp.full((Q, self.n_vertices), fill, dtype)
        return base.at[rows, self.sources].set(value)

    def source_frontier(self) -> jax.Array:
        """bool[Q, V]: row q's frontier seeded at its own source vertex."""
        if not self.batched or self.sources is None:
            raise ValueError("source_frontier() needs batched mode with sources=")
        Q = self.windows.shape[0]
        rows = jnp.arange(Q, dtype=jnp.int32)
        return jnp.zeros((Q, self.n_vertices), bool).at[
            rows, self.sources].set(True)

    # -- one relaxation round over the hoisted view ------------------------

    def step(
        self,
        frontier: jax.Array,            # bool[V] | bool[W, V]
        src_state,                      # pytree of [V, ...] | [W, V, ...]
        relax: Callable,
        combine: str,
        *,
        compute_touched: bool = False,
    ) -> Tuple[Any, Optional[jax.Array]]:
        """One relaxation round.  All loop-invariant masking is precomputed;
        the round pays only the frontier gather, the relax, and the combine.
        ``touched`` (segments that received a valid contribution) costs an
        extra segment-sum and is skipped unless requested — the fixpoint
        loops derive their frontiers from the combined values instead."""
        if self.batched:
            def per_window(wvalid, f, state):
                valid = wvalid & f[self.from_v]
                gathered = jax.tree_util.tree_map(
                    lambda a: a[self.from_v], state)
                cand, extra = relax(self.edges, gathered)
                return cand, valid & extra

            cand, valid = jax.vmap(per_window)(self.valid, frontier, src_state)
            out = combine_windows_for_plan(
                self.plan, cand, self.to_v, self.n_vertices, combine,
                masks=valid, use_layout=self.use_layout,
            )
            if not compute_touched:
                return out, None
            touched = jax.vmap(
                lambda v: segment_combine(
                    v.astype(jnp.int32), self.to_v, self.n_vertices, "sum",
                    axis=self.plan.edge_axis if self.plan else None)
            )(valid) > 0
            return out, touched

        valid = self.valid & frontier[self.from_v]
        gathered = jax.tree_util.tree_map(lambda a: a[self.from_v], src_state)
        cand, extra = relax(self.edges, gathered)
        valid &= extra
        out = combine_for_plan(
            self.plan, cand, self.to_v, self.n_vertices, combine,
            mask=valid, use_layout=self.use_layout,
        )
        if not compute_touched:
            return out, None
        touched = segment_combine(
            valid.astype(jnp.int32), self.to_v, self.n_vertices, "sum",
            axis=self.plan.edge_axis if self.plan else None,
        ) > 0
        return out, touched

    @classmethod
    def for_view(
        cls,
        edges,
        window=None,
        *,
        windows=None,
        sources=None,
        plan: AccessPlan,
        n_vertices: int,
        direction: str = "out",
        check_window: bool = True,
        max_rounds: int = 0,
    ) -> "FixpointRunner":
        """Wrap an EXTERNALLY-built (or externally-ADVANCED) edge view — the
        incremental server's ring views enter the runner here: the view's
        slot order is irrelevant to the masked segment combines, so a
        ring-advanced view runs identically to a cold gather."""
        return cls(
            edges, window, windows=windows, sources=sources, plan=plan,
            n_vertices=n_vertices, direction=direction,
            check_window=check_window, max_rounds=max_rounds,
        )

    # -- the loop driver ---------------------------------------------------

    def run(self, cond: Callable, body: Callable, init, *,
            with_rounds: bool = False):
        """``while (round < max_rounds) and cond(state): state = body(state,
        round)``.  ``cond`` is typically frontier emptiness (``jnp.any`` of
        the state's frontier leaf) or a changed flag; the round counter is
        handed to ``body`` for hop-counting algorithms.  ``with_rounds=True``
        additionally returns the executed round count (i32 scalar)."""

        def loop_cond(carry):
            rnd, state = carry
            return (rnd < self.max_rounds) & cond(state)

        def loop_body(carry):
            rnd, state = carry
            return rnd + 1, body(state, rnd)

        rnd, final = jax.lax.while_loop(
            loop_cond, loop_body, (jnp.int32(0), init))
        return (final, rnd) if with_rounds else final

    def run_with_metrics(
        self, cond: Callable, body: Callable, init, *,
        frontier_trace: bool = False,
    ) -> Tuple[Any, FixpointMetrics]:
        """Metered loop driver: ``body(state, rnd) -> (state, touched)``
        (``touched`` from ``step(..., compute_touched=True)``); returns
        ``(final_state, FixpointMetrics)``.  Costs one extra segment-sum per
        round over the unmetered ``run`` — serving opts in per query.

        ``frontier_trace=True`` additionally records the per-round frontier
        occupancy into ``FixpointMetrics.frontier_trace``: an
        i32[max_rounds] buffer whose entry r is round r's touched-vertex
        count (summed over the batch rows), -1 for rounds never executed.
        The buffer shape is static (``max_rounds``), so the metered trace
        stays one jittable program."""

        trace0 = (
            jnp.full(self.max_rounds, -1, jnp.int32) if frontier_trace
            else jnp.zeros((0,), jnp.int32)
        )

        def loop_cond(carry):
            rnd, state, _touched_total, _trace = carry
            return (rnd < self.max_rounds) & cond(state)

        def loop_body(carry):
            rnd, state, touched_total, trace = carry
            state, touched = body(state, rnd)
            occ = jnp.sum(touched.astype(jnp.int32))
            if frontier_trace:
                trace = jax.lax.dynamic_update_index_in_dim(
                    trace, occ, rnd, 0)
            return rnd + 1, state, touched_total + occ, trace

        rnd, final, touched_total, trace = jax.lax.while_loop(
            loop_cond, loop_body, (jnp.int32(0), init, jnp.int32(0), trace0))
        return final, FixpointMetrics(
            rounds=rnd, touched_total=touched_total,
            frontier_trace=trace if frontier_trace else None)


__all__ = ["FixpointRunner", "FixpointMetrics"]
