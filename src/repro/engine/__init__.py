"""The access-plan engine: one planner, pluggable execution backends.

    plan = plan_query(g, tger, window, access="auto", backend="pallas_tiled")
    arrival = earliest_arrival(g, src, window, tger, plan=plan)

See DESIGN.md §1 for the layering (planner -> plan -> backend) and §2 for
the static-shape budget ladder the plan encodes.
"""
from repro.engine.plan import (  # noqa: F401
    AccessPlan,
    BACKENDS,
    METHODS,
    decision_for,
    make_plan,
    per_vertex_window_budget,
    plan_query,
)
from repro.engine.backends import (  # noqa: F401
    ExecutionBackend,
    PallasTiledBackend,
    XlaSegmentBackend,
    combine_for_plan,
    get_backend,
    segment_combine,
)
from repro.engine.fixpoint import FixpointRunner  # noqa: F401

__all__ = [
    "FixpointRunner",
    "AccessPlan",
    "plan_query",
    "make_plan",
    "decision_for",
    "per_vertex_window_budget",
    "METHODS",
    "BACKENDS",
    "ExecutionBackend",
    "XlaSegmentBackend",
    "PallasTiledBackend",
    "get_backend",
    "combine_for_plan",
    "segment_combine",
]
