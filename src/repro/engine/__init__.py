"""The access-plan engine: one planner, pluggable execution backends.

    plan = plan_query(g, tger, window, access="auto", backend="pallas_tiled")
    arrival = earliest_arrival(g, src, window, tger, plan=plan)

See DESIGN.md §1 for the layering (planner -> plan -> backend) and §2 for
the static-shape budget ladder the plan encodes.
"""
from repro.engine.plan import (  # noqa: F401
    AccessPlan,
    BACKENDS,
    METHODS,
    decision_for,
    heavy_window_budget,
    make_plan,
    per_vertex_window_budget,
    plan_batch,
    plan_query,
    rung,
)
from repro.engine.queries import (  # noqa: F401
    DEEP_ALGORITHMS,
    DEFAULT_COST_CLASS,
    QueryBatch,
    QueryRow,
    QuerySpec,
    SOURCE_FREE,
    bucket_capacity,
    cost_class_for,
    dedup_rows,
)
from repro.engine.backends import (  # noqa: F401
    ExecutionBackend,
    PallasTiledBackend,
    XlaSegmentBackend,
    combine_for_plan,
    get_backend,
    segment_combine,
)
from repro.engine.fixpoint import FixpointMetrics, FixpointRunner  # noqa: F401

__all__ = [
    "FixpointRunner",
    "FixpointMetrics",
    "AccessPlan",
    "QueryBatch",
    "QueryRow",
    "QuerySpec",
    "SOURCE_FREE",
    "DEEP_ALGORITHMS",
    "DEFAULT_COST_CLASS",
    "cost_class_for",
    "bucket_capacity",
    "plan_query",
    "plan_batch",
    "make_plan",
    "decision_for",
    "per_vertex_window_budget",
    "heavy_window_budget",
    "rung",
    "METHODS",
    "BACKENDS",
    "ExecutionBackend",
    "XlaSegmentBackend",
    "PallasTiledBackend",
    "get_backend",
    "combine_for_plan",
    "segment_combine",
]
