"""QueryBatch: the first-class multi-tenant query unit (DESIGN.md §7.4).

A serving tenant asks ``(algorithm, source, window, params)``; the
multi-tenant engine answers a whole SET of those from one shared temporal
structure — one union AccessPlan, one ring advance, one fused dispatch.
This module is the host-side normal form that planning (`plan_batch`) and
serving (`serve.serve_batch` / `sweep_incremental`) agree on:

  * :class:`QuerySpec` — one tenant's request: an algorithm name, zero or
    more source vertices, one window, and the algorithm kwargs.  A spec
    with S sources EXPANDS into S rows (the "(algorithm × source ×
    window)" row model: every row is one [V] answer).
  * :class:`QueryBatch` — an ordered tuple of specs.  ``groups()`` buckets
    the expanded rows by ``(algorithm, params)`` — the unit the batched
    ``*_over_view`` solvers consume (each group solves as ONE [Q_g, V]
    fixpoint with the source axis vmapped alongside the window axis) —
    and ``signature()`` is the static shape descriptor that rides the
    AccessPlan cache key, so jitted programs specialize per batch SHAPE
    (group structure and row counts), never per batch VALUES (sources and
    window bounds stay dynamic).

Source-free algorithms (pagerank, cc, kcore) take ``sources=None`` /
``()`` — their rows are window-only queries.  The module is deliberately
dependency-light (host-side dataclasses + numpy): the engine planner and
the serving layer both import it, neither through the other.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

# Algorithms whose rows carry no source vertex.  Kept here (not in serve)
# so spec normalization needs no import of the serving dispatch table;
# serve validates against its own registry again at dispatch time.
SOURCE_FREE = ("pagerank", "cc", "kcore")

# Default cost class per algorithm (DESIGN.md §7.6): "deep" tenants run
# long fixpoints (pagerank's fixed iteration ladder, betweenness's
# two-pass DAG accumulation) and would stall the fused dispatch every
# cheap tenant shares; the serving daemon splits fused schedules by class
# and round-robins the deep classes across advances.  A QuerySpec may
# override with an explicit ``cost_class=``.
DEEP_ALGORITHMS = ("pagerank", "betweenness")
DEFAULT_COST_CLASS = "cheap"


def cost_class_for(algorithm: str) -> str:
    return "deep" if algorithm in DEEP_ALGORITHMS else DEFAULT_COST_CLASS


def bucket_capacity(n: int, prev_cap: int = 0) -> int:
    """The admission bucket ladder (DESIGN.md §7.6): group row counts pad
    to power-of-two capacities so a tenant admitted (or retired) INSIDE a
    bucket changes no static shape — the fused step's jit cache hits and
    the donated state is consumed warm.  ``prev_cap`` applies hysteresis:
    a resident group keeps its capacity while ``prev_cap // 4 < n <=
    prev_cap`` (shrinking the bucket on every departure would thrash the
    cache the ladder exists to pin)."""
    n = max(int(n), 1)
    if prev_cap and prev_cap // 4 < n <= prev_cap:
        return int(prev_cap)
    return 1 << (n - 1).bit_length()


def _params_token(params) -> Tuple[Tuple[str, Any], ...]:
    if isinstance(params, dict):
        items = params.items()
    else:
        items = tuple(params)
    return tuple(sorted((str(k), v) for k, v in items))


@dataclasses.dataclass(frozen=True)
class QuerySpec:
    """One tenant's request.  ``sources`` is a tuple of seed vertices
    (empty for source-free algorithms); ``params`` the algorithm kwargs as
    a sorted item tuple (hashable — it becomes part of the jit-static
    group schedule)."""

    algorithm: str
    window: Tuple[int, int]
    sources: Tuple[int, ...] = ()
    params: Tuple[Tuple[str, Any], ...] = ()
    cost_class: Optional[str] = None    # None = derive from the algorithm
    pinned: bool = False                # window is historical: never re-anchor

    @classmethod
    def make(cls, algorithm: str, window, sources=None, cost_class=None,
             pinned=False, **params) -> "QuerySpec":
        """Normalizing constructor: scalar/sequence sources, any window
        pair, kwargs as params.  ``cost_class`` overrides the per-algorithm
        default (DEEP_ALGORITHMS -> "deep", else "cheap") — it tags the
        spec for the serving daemon's class-split scheduling and is NOT
        part of the group key or the batch signature.  ``pinned=True``
        marks a time-travel tenant: the daemon must serve its window
        VERBATIM (through the cold tier when it precedes the hot horizon)
        and ``tick`` must never re-anchor it to the advancing frontier."""
        if sources is None:
            src: Tuple[int, ...] = ()
        elif np.ndim(sources) == 0:
            src = (int(sources),)
        else:
            src = tuple(int(s) for s in np.asarray(sources).reshape(-1))
        if algorithm in SOURCE_FREE and src:
            raise ValueError(f"{algorithm} is source-free: pass sources=None")
        if algorithm not in SOURCE_FREE and not src:
            raise ValueError(f"{algorithm} needs at least one source")
        return cls(
            algorithm=str(algorithm),
            window=(int(window[0]), int(window[1])),
            sources=src,
            params=_params_token(params),
            cost_class=None if cost_class is None else str(cost_class),
            pinned=bool(pinned),
        )

    @property
    def resolved_cost_class(self) -> str:
        return (self.cost_class if self.cost_class is not None
                else cost_class_for(self.algorithm))

    @property
    def n_rows(self) -> int:
        return max(len(self.sources), 1)


@dataclasses.dataclass(frozen=True)
class QueryRow:
    """One expanded (algorithm, source, window) row: the atomic unit of
    matching/reuse in the incremental server.  ``source`` is None for
    source-free algorithms.  ``spec_index`` points back at the originating
    spec (result navigation)."""

    algorithm: str
    params: Tuple[Tuple[str, Any], ...]
    source: Optional[int]
    window: Tuple[int, int]
    spec_index: int

    @property
    def group_key(self) -> Tuple[str, tuple]:
        return (self.algorithm, self.params)


@dataclasses.dataclass(frozen=True)
class QueryBatch:
    """An ordered set of :class:`QuerySpec` — THE unit of multi-tenant
    planning and serving."""

    specs: Tuple[QuerySpec, ...]

    @classmethod
    def make(cls, specs: Sequence[QuerySpec]) -> "QueryBatch":
        specs = tuple(specs)
        if not specs:
            raise ValueError("a QueryBatch needs at least one QuerySpec")
        return cls(specs=specs)

    # -- the row/group normal form ----------------------------------------

    def rows(self) -> List[QueryRow]:
        """Expanded rows, batch order: specs in order, a spec's sources in
        order."""
        out: List[QueryRow] = []
        for i, spec in enumerate(self.specs):
            if spec.sources:
                for s in spec.sources:
                    out.append(QueryRow(spec.algorithm, spec.params, s,
                                        spec.window, i))
            else:
                out.append(QueryRow(spec.algorithm, spec.params, None,
                                    spec.window, i))
        return out

    def groups(self) -> Dict[Tuple[str, tuple], List[QueryRow]]:
        """Rows bucketed by ``(algorithm, params)`` in first-appearance
        order — one bucket = one batched ``*_over_view`` solve.  The order
        is deterministic so a shape-stable batch stream produces a stable
        group schedule (jit-cache pinning)."""
        out: Dict[Tuple[str, tuple], List[QueryRow]] = {}
        for row in self.rows():
            out.setdefault(row.group_key, []).append(row)
        return out

    @property
    def n_rows(self) -> int:
        return sum(spec.n_rows for spec in self.specs)

    def union(self) -> Tuple[int, int]:
        return (
            min(s.window[0] for s in self.specs),
            max(s.window[1] for s in self.specs),
        )

    def windows(self) -> List[Tuple[int, int]]:
        """Distinct windows, first-appearance order (what the union planner
        budgets over)."""
        seen: Dict[Tuple[int, int], None] = {}
        for s in self.specs:
            seen.setdefault(s.window, None)
        return list(seen)

    def by_cost_class(self) -> Dict[str, "QueryBatch"]:
        """Specs split into per-cost-class sub-batches, first-appearance
        class order — the unit the serving daemon schedules round-robin
        (DESIGN.md §7.6): each class gets its own fused schedule and
        advance chain, so a deep tenant's 100-iteration while_loop never
        sits in the dispatch a cheap tenant's latency waits on."""
        out: Dict[str, List[QuerySpec]] = {}
        for spec in self.specs:
            out.setdefault(spec.resolved_cost_class, []).append(spec)
        return {c: QueryBatch.make(s) for c, s in out.items()}

    def signature(self, bucketed: bool = False) -> str:
        """The static batch-SHAPE descriptor that rides the AccessPlan
        cache key: per-group algorithm names + row counts (readable) plus
        a crc of the full (algorithm, params, n_rows) group structure
        (collision-safe for distinct param sets).  Window bounds and
        source ids are deliberately EXCLUDED — they are dynamic arguments
        of the fused step, and keying on them would defeat the jit-cache
        pinning the serving soak asserts.  ``bucketed=True`` keys the
        BUCKETED row capacities instead of the exact counts (the admission
        ladder of DESIGN.md §7.6), so tenant churn inside a bucket reuses
        the same plan."""
        parts = []
        desc = []
        for (alg, params), rows in self.groups().items():
            n = bucket_capacity(len(rows)) if bucketed else len(rows)
            parts.append(f"{alg}x{n}{'b' if bucketed else ''}")
            desc.append((alg, params, n))
        crc = zlib.crc32(repr(desc).encode()) & 0xFFFFFFFF
        return "+".join(parts) + f"#{crc:08x}"


def dedup_rows(sources, windows):
    """Cross-query row dedup within one (algorithm, params) group: rows
    with identical ``(source, window)`` collapse to ONE solved row.

    ``sources`` is a sequence of source ids (None entries for source-free
    rows); ``windows`` an i32[Q, 2] array.  Returns ``(unique_sources,
    unique_windows, inverse)`` — unique rows in first-appearance order and
    a ``tuple`` mapping every original row to its unique row, so the
    engine solves the unique rows and FANS OUT at assembly
    (``solved[inverse]``).  Identical tenants (the common many-users-one-
    dashboard shape) then cost one fixpoint row, not Q — and the sharded
    row partition (``distributed.query_shard.row_partition``) operates on
    the already-deduplicated axis."""
    windows = np.asarray(windows, np.int32).reshape(-1, 2)
    seen: Dict[Tuple[Any, int, int], int] = {}
    u_sources: List[Any] = []
    u_windows: List[Tuple[int, int]] = []
    inverse: List[int] = []
    for s, w in zip(sources, windows):
        key = (s, int(w[0]), int(w[1]))
        j = seen.get(key)
        if j is None:
            j = len(u_sources)
            seen[key] = j
            u_sources.append(s)
            u_windows.append((int(w[0]), int(w[1])))
        inverse.append(j)
    return (u_sources, np.asarray(u_windows, np.int32).reshape(-1, 2),
            tuple(inverse))


__all__ = ["QuerySpec", "QueryRow", "QueryBatch", "SOURCE_FREE",
           "DEEP_ALGORITHMS", "DEFAULT_COST_CLASS", "cost_class_for",
           "bucket_capacity", "dedup_rows"]
