"""Frontier-rung ladder: sparse fixpoint rounds proportional to the live
frontier (DESIGN.md §7.9).

Every fixpoint in this repo relaxes the ENTIRE hoisted edge view each
round — the frontier is only a mask (``valid & frontier[from_v]`` in
``FixpointRunner.step``), so a deep chain pays O(rounds × E′) while the
frontier holds a handful of vertices for most of the tail.  Kairos's
fork-join edgeMap iterates only *active* adjacency lists; the XLA
translation here is a **ladder of statically-shaped sparse segments**:

  * a source-grouped **companion view** (:class:`FrontierView`) of the
    hoisted edge view — a permutation of slot ids sorted by the slot's
    source vertex plus a CSR offset table — built once per cold view
    (host argsort) and delta-advanced with the ring (the slot order is
    positionally stable, so an advance touches only the entering slots:
    the same concat/shift bookkeeping as ``index_ring_view``);
  * a **sparse round** that pads the frontier to a static pow2 vertex
    rung (``engine.queries.bucket_capacity`` — the admission-bucket
    machinery), expands it through the companion offsets into at most
    ``erung`` frontier-incident edge slots, and runs the algorithm's
    relax + masked segment combine over ONLY those slots.  Integer
    min/max/sum combines are order-independent, so a sparse round is
    bit-identical to the dense masked round over the same edges;
  * a **host-level segment loop** (:func:`run_laddered`): dense segments
    while the frontier is wide, then descent through sparse segments at
    static ``(vrung, erung)`` rungs.  Each segment is one jitted
    ``while_loop`` keyed on ``(plan statics, rung)`` — after warmup the
    whole ladder is a jit-cache hit across queries, and the per-segment
    host sync is the only non-fused dispatch.  Rung overflow (frontier
    outgrowing the static pads) exits the segment BEFORE an uncovered
    round runs — never a silent truncation.

The ladder engages only on host-level calls (concrete arrays) under a
plan with ``plan.ladder > 0`` — inside a trace (the fused serving step,
nested jits) :func:`ladder_eligible` is False and the dense program runs
untouched, preserving the one-dispatch contract.
"""
from __future__ import annotations

import functools
from typing import Callable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hostcache import identity_cache
from repro.engine.backends import segment_combine
from repro.engine.queries import bucket_capacity

# sparse-segment edge rung never drops below this (a handful of tiny
# segments would pay more in host syncs than they save in FLOPs); the
# descent hysteresis is disabled at the floor so a zero-out-degree
# frontier still executes its (empty) round and converges.
ERUNG_FLOOR = 64
# hand off dense -> sparse when the frontier's summed structural degree
# drops under this fraction of the view (sparse rounds cost O(V + erung)
# per row against the dense round's O(E'); at E'/4 the pow2 pad still
# leaves a margin).
DENSE_HANDOFF_DIV = 4

# trace-time event log: every jitted segment body appends its tag ONCE
# per compilation, so a warmed ladder adds nothing here — benchmarks
# assert zero retraces on repeated same-shape queries from this log.
_TRACE_LOG: List[str] = []


def ladder_trace_log() -> Tuple[str, ...]:
    return tuple(_TRACE_LOG)


def ladder_trace_count() -> int:
    return len(_TRACE_LOG)


class FrontierView(NamedTuple):
    """Source-grouped companion of one edge view: ``perm`` lists the
    view's slot ids sorted by ``(from_v[slot], slot)``; ``offsets`` is the
    CSR fence (``perm[offsets[v]:offsets[v+1]]`` are vertex v's slots);
    ``degs`` its diff (structural out-slots per vertex — masked padding
    slots included; they are re-masked at gather time, the count only
    feeds rung selection).  All slots appear exactly once, so the
    companion never needs rebuilding when only the validity mask moves."""

    perm: jax.Array      # i32[E'] slot ids grouped by source vertex
    offsets: jax.Array   # i32[V + 1]
    degs: jax.Array      # i32[V]


def build_frontier_view(from_v, n_vertices: int) -> FrontierView:
    """Cold host-side build: one stable argsort over the view's source
    endpoints (every slot, masked padding included)."""
    fv = np.asarray(from_v)
    perm = np.argsort(fv, kind="stable").astype(np.int32)
    degs = np.bincount(fv, minlength=n_vertices).astype(np.int32)
    offsets = np.zeros(n_vertices + 1, np.int32)
    np.cumsum(degs, out=offsets[1:])
    return FrontierView(jnp.asarray(perm), jnp.asarray(offsets),
                        jnp.asarray(degs))


def advance_frontier_view(fv: FrontierView, slots, old_from, new_from,
                          n_vertices: int) -> FrontierView:
    """Delta-advance the companion for a ring advance that rewrote
    ``slots`` (distinct slot ids, any order — wrap-around included) from
    source ``old_from[i]`` to ``new_from[i]``: remove the old (vertex,
    slot) entries from the sorted grouping, insert the new ones.  O(E' +
    Δ log E') host work — the same order as the advance's own mask
    recompute — and exactly equal to a cold rebuild over the advanced
    endpoints (property-tested, including wrap-around)."""
    perm = np.asarray(fv.perm)
    degs = np.asarray(fv.degs).copy()
    C = perm.shape[0]
    slots = np.asarray(slots, np.int64)
    old_from = np.asarray(old_from, np.int64)
    new_from = np.asarray(new_from, np.int64)
    if slots.size == 0:
        return fv
    # the sorted grouping IS a sorted key array keys = owner * C + slot
    owner = np.repeat(np.arange(n_vertices, dtype=np.int64),
                      np.diff(np.asarray(fv.offsets)))
    keys = owner * C + perm
    drop = np.searchsorted(keys, np.sort(old_from * C + slots))
    keys = np.delete(keys, drop)
    ins = np.sort(new_from * C + slots)
    keys = np.insert(keys, np.searchsorted(keys, ins), ins)
    np.subtract.at(degs, old_from, 1)
    np.add.at(degs, new_from, 1)
    offsets = np.zeros(n_vertices + 1, np.int32)
    np.cumsum(degs, out=offsets[1:])
    return FrontierView(jnp.asarray((keys % C).astype(np.int32)),
                        jnp.asarray(offsets), jnp.asarray(degs))


@identity_cache(16)
def _companion_cached(from_v, n_vertices: int) -> FrontierView:
    return build_frontier_view(from_v, n_vertices)


def companion_for_view(from_v, n_vertices: int) -> FrontierView:
    """Identity-cached companion build: repeated laddered solves over the
    SAME resident view arrays (the serving cold tier re-solving a stitched
    ring, a benchmark loop) pay the argsort once."""
    return _companion_cached(from_v, int(n_vertices))


def ladder_eligible(plan, edges, *arrays) -> bool:
    """True when a host-level laddered solve may run: the plan opted in
    (``ladder > 0``), the edge axis is unsharded (the sparse gather order
    is per-device local and a psum across shards would double-count), and
    the call is NOT under a trace — fused serving steps and nested jits
    keep the dense one-dispatch program.  Extra ``arrays`` (windows, warm
    init, sources) are tracer-checked too: a jitted caller can close over
    a concrete view while tracing its windows."""
    if (plan is None or not getattr(plan, "ladder", 0)
            or plan.edge_axis is not None):
        return False
    leaves = [edges.src, *(a for a in arrays if a is not None)]
    return not any(isinstance(a, jax.core.Tracer) for a in leaves)


# ---------------------------------------------------------------------------
# the sparse gather: frontier row -> covered edge-slot rows
# ---------------------------------------------------------------------------

def _gather_row(perm, offsets, f_row, vrung: int, erung: int, V: int):
    av = jnp.nonzero(f_row, size=vrung, fill_value=V)[0].astype(jnp.int32)
    real = av < V
    lo = offsets[jnp.where(real, av, 0)]
    hi = offsets[jnp.where(real, av + 1, 0)]
    deg = jnp.where(real, hi - lo, 0)
    csum = jnp.cumsum(deg)
    total = csum[-1]
    pos = jnp.arange(erung, dtype=jnp.int32)
    own = jnp.searchsorted(csum, pos, side="right").astype(jnp.int32)
    own = jnp.minimum(own, vrung - 1)
    within = pos - (csum[own] - deg[own])
    slot_idx = jnp.clip(lo[own] + within, 0, perm.shape[0] - 1)
    return perm[slot_idx], pos < total


def gather_frontier_slots(fv: FrontierView, frontier, vrung: int,
                          erung: int, n_vertices: int):
    """[Q, erung] slot ids covering EVERY frontier-incident slot of every
    row, plus the coverage mask (False = pow2 padding).  Exact coverage
    requires per-row occupancy <= vrung and summed degree <= erung — the
    segment conds guard both, exiting to the host for a bigger rung
    instead of truncating."""
    return jax.vmap(
        lambda f: _gather_row(fv.perm, fv.offsets, f, vrung, erung,
                              n_vertices)
    )(frontier)


def sparse_window_valid(edges, windows, slots, cov):
    """Per-row validity of gathered slots: coverage ∧ structural mask ∧
    window membership — the same predicate the dense rounds precompute as
    ``runner.valid``, evaluated only on the gathered slots.  Returns
    ``(valid, t_start, t_end)`` at the slots."""
    from repro.core.predicates import in_window

    ts = edges.t_start[slots]
    te = edges.t_end[slots]
    ok = cov & edges.mask[slots] & in_window(
        ts, te, windows[:, 0:1], windows[:, 1:2])
    return ok, ts, te


def rowwise_combine(vals, seg_ids, n_segments: int, op: str, mask):
    """vmapped masked segment combine: the sparse-round counterpart of
    ``combine_windows_for_plan`` (integer min/max/sum are order-free, so
    this matches the dense backends bit-for-bit on the same multiset)."""
    return jax.vmap(
        lambda v, s, m: segment_combine(v, s, n_segments, op, mask=m)
    )(vals, seg_ids, mask)


def take_rows(state, idx):
    """[Q, V] state gathered at per-row indices [Q, K] -> [Q, K]."""
    return jnp.take_along_axis(state, idx, axis=1)


# ---------------------------------------------------------------------------
# ladder segments
# ---------------------------------------------------------------------------

class LadderSpec(NamedTuple):
    """One algorithm's ladder contract (module-level, hashable — it keys
    the segment jit caches together with the rungs and plan statics).

    ``dense_round(edges, valid, windows, plan, state, rnd, V) -> state``
    replicates the algorithm's existing batched body exactly (bit-identity
    anchor).  ``sparse_round(edges, windows, plan, gathered, state, rnd,
    V) -> state`` consumes the driver's per-companion ``(slots, cov)``
    gathers.  ``frontier(state) -> bool[Q, V]`` exposes the live set the
    rung selection and convergence test read."""

    name: str
    dense_round: Callable
    sparse_round: Callable
    frontier: Callable


def _measures(spec: LadderSpec, state, deg):
    f = spec.frontier(state)
    occ = jnp.max(jnp.sum(f.astype(jnp.int32), axis=1))
    sumdeg = jnp.max(jnp.sum(jnp.where(f, deg, 0), axis=1))
    return occ, sumdeg


@functools.partial(
    jax.jit,
    static_argnames=("spec", "n_vertices", "max_rounds", "cutoff", "cap"),
)
def _dense_segment(spec: LadderSpec, edges, valid, windows, plan, deg,
                   state, rnd, *, n_vertices: int, max_rounds: int,
                   cutoff: int, cap: int):
    _TRACE_LOG.append(f"{spec.name}:dense:{plan.cache_key}")

    def cond(carry):
        r, s, occ, sumdeg = carry
        sparse_ok = (sumdeg <= cutoff) & (occ <= cap)
        return (r < max_rounds) & (occ > 0) & ~sparse_ok

    def body(carry):
        r, s, _, _ = carry
        s = spec.dense_round(edges, valid, windows, plan, s, r, n_vertices)
        occ, sumdeg = _measures(spec, s, deg)
        return r + 1, s, occ, sumdeg

    occ0, sumdeg0 = _measures(spec, state, deg)
    return jax.lax.while_loop(cond, body, (rnd, state, occ0, sumdeg0))


@functools.partial(
    jax.jit,
    static_argnames=("spec", "n_vertices", "max_rounds", "vrung", "erung",
                     "at_floor"),
)
def _sparse_segment(spec: LadderSpec, edges, windows, plan, companions,
                    deg, state, rnd, *, n_vertices: int, max_rounds: int,
                    vrung: int, erung: int, at_floor: bool):
    _TRACE_LOG.append(
        f"{spec.name}:sparse:v{vrung}e{erung}:{plan.cache_key}")

    def cond(carry):
        r, s, occ, sumdeg = carry
        ok = (occ > 0) & (occ <= vrung) & (sumdeg <= erung)
        if not at_floor:
            # descent hysteresis (bucket_capacity's prev//4 band): a
            # frontier that shrank past a quarter of the rung exits so the
            # host re-enters at a smaller static rung.
            ok &= sumdeg > erung // 4
        return (r < max_rounds) & ok

    def body(carry):
        r, s, _, _ = carry
        f = spec.frontier(s)
        gathered = tuple(
            gather_frontier_slots(c, f, vrung, erung, n_vertices)
            for c in companions
        )
        s = spec.sparse_round(edges, windows, plan, gathered, s, r,
                              n_vertices)
        occ, sumdeg = _measures(spec, s, deg)
        return r + 1, s, occ, sumdeg

    occ0, sumdeg0 = _measures(spec, state, deg)
    return jax.lax.while_loop(cond, body, (rnd, state, occ0, sumdeg0))


def choose_rungs(occ: int, sumdeg: int, prev_vrung: int, prev_erung: int,
                 *, cap: int, n_slots: int, n_vertices: int
                 ) -> Tuple[int, int]:
    """Host-side rung selection for the next sparse segment: pow2 pads
    with ``bucket_capacity`` hysteresis (a frontier inside the previous
    rung's (cap/4, cap] band keeps the rung — same-shape queries then
    replay the identical segment sequence and every jit lookup hits).
    Monotone in (occ, sumdeg): shrinking inputs never pick a bigger rung
    (property-tested)."""
    from repro.engine.plan import rung

    vrung = min(bucket_capacity(max(occ, 1), prev_vrung),
                rung(min(cap, n_vertices)))
    floor = min(ERUNG_FLOOR, rung(n_slots))
    erung = max(min(bucket_capacity(max(sumdeg, 1), prev_erung),
                    rung(n_slots)), floor)
    return vrung, erung


def run_laddered(
    spec: LadderSpec,
    edges,
    windows,                         # i32[Q, 2]
    valid,                           # bool[Q, E'] precomputed dense validity
    plan,
    n_vertices: int,
    state,
    *,
    companions: Tuple[FrontierView, ...],
    max_rounds: int,
    segments: Optional[list] = None,
):
    """The host-level segment loop (DESIGN.md §7.9): dense jitted
    segments until the frontier's summed degree drops under the handoff
    cutoff, then sparse segments at static ``(vrung, erung)`` rungs with
    hysteresis descent; overflow (frontier outgrowing a rung) exits to the
    host and re-enters dense or at a bigger rung — never truncating.

    Returns ``(final_state, rounds)`` with ``rounds`` the global executed
    round count (i32 scalar), matching the dense ``run(with_rounds=True)``
    accounting.  ``segments``, if a list, collects ``(kind, vrung, erung,
    round_count)`` per executed segment for observability and tests."""
    E = int(edges.src.shape[0])
    cap = int(plan.ladder)
    cutoff = max(E // DENSE_HANDOFF_DIV, 1)
    deg = companions[0].degs
    for c in companions[1:]:
        deg = deg + c.degs
    floor = min(ERUNG_FLOOR, 1 << (max(E, 1) - 1).bit_length())

    rnd = jnp.int32(0)
    rnd_i = 0
    while True:
        rnd, state, occ, sumdeg = _dense_segment(
            spec, edges, valid, windows, plan, deg, state, rnd,
            n_vertices=n_vertices, max_rounds=max_rounds, cutoff=cutoff,
            cap=cap)
        prev = rnd_i
        occ_i, sd_i, rnd_i = int(occ), int(sumdeg), int(rnd)
        if segments is not None and rnd_i > prev:
            segments.append(("dense", 0, 0, rnd_i - prev))
        if occ_i == 0 or rnd_i >= max_rounds:
            break
        vrung = erung = 0
        while (0 < occ_i <= cap and sd_i <= cutoff
               and rnd_i < max_rounds):
            vrung, erung = choose_rungs(
                occ_i, sd_i, vrung, erung, cap=cap, n_slots=E,
                n_vertices=n_vertices)
            rnd, state, occ, sumdeg = _sparse_segment(
                spec, edges, windows, plan, companions, deg, state, rnd,
                n_vertices=n_vertices, max_rounds=max_rounds,
                vrung=vrung, erung=erung, at_floor=(erung <= floor))
            prev = rnd_i
            occ_i, sd_i, rnd_i = int(occ), int(sumdeg), int(rnd)
            if segments is not None:
                segments.append(("sparse", vrung, erung, rnd_i - prev))
        if occ_i == 0 or rnd_i >= max_rounds:
            break
    return state, rnd


__all__ = [
    "FrontierView",
    "build_frontier_view",
    "advance_frontier_view",
    "companion_for_view",
    "ladder_eligible",
    "gather_frontier_slots",
    "sparse_window_valid",
    "rowwise_combine",
    "take_rows",
    "LadderSpec",
    "choose_rungs",
    "run_laddered",
    "ladder_trace_log",
    "ladder_trace_count",
    "ERUNG_FLOOR",
]
