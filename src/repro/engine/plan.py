"""AccessPlan: the one planning surface for every access path (DESIGN.md §1).

The paper's selective indexing (§5) picks the cheapest access method per
query; before this layer existed the choice was a bare string threaded by
hand through every algorithm, with the decision logic split across the
selective cost model and the edgemap.  ``plan_query`` is the single
host-side planner that turns (graph, TGER, window — or a batch of
windows) into an :class:`AccessPlan` — method + budgets + execution
backend — which the edgemap, all algorithms, and the distributed round
builder consume.

``AccessPlan`` is a registered-dataclass pytree: the method/budget/backend
fields are static metadata (they specialize the jitted program — exactly
one compilation per budget-ladder rung), while the Pallas tile-layout
arrays are ordinary pytree leaves so plans flow through ``jax.jit``
unhindered.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hostcache import identity_cache
from repro.core.selective import AccessDecision, CostModel, decide_access
from repro.core.temporal_graph import TemporalGraph
from repro.core.tger import TGERIndex, window_positions_host

METHODS = ("scan", "index", "hybrid")
BACKENDS = ("xla_segment", "pallas_tiled")
TIERS = ("hot", "cold", "split")

DEFAULT_TILE_V = 512
DEFAULT_BLOCK_E = 1024


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AccessPlan:
    """One algorithm run's access decision, produced host-side.

    Dynamic leaves carry the (per-graph, build-once) destination-tile
    layout used by the ``pallas_tiled`` backend; they are zero-length
    placeholders on the ``xla_segment`` backend.  Everything else is static
    so jitted programs specialize per plan shape.
    """

    # -- dynamic (pytree leaves) --------------------------------------------
    layout_perm: jax.Array        # i32[Ep] dst-tile-grouped edge ids (-1 pad)
    layout_block_tile: jax.Array  # i32[NB] output tile owned by each block
    # -- static (pytree metadata) -------------------------------------------
    method: str = dataclasses.field(metadata=dict(static=True))        # scan|index|hybrid
    backend: str = dataclasses.field(metadata=dict(static=True))       # xla_segment|pallas_tiled
    budget: int = dataclasses.field(metadata=dict(static=True))        # global gather budget (index)
    per_vertex_budget: int = dataclasses.field(metadata=dict(static=True))  # hybrid heavy-vertex budget
    exchange_budget: int = dataclasses.field(metadata=dict(static=True))    # distributed top-K wire budget (0 = dense)
    tile_v: int = dataclasses.field(metadata=dict(static=True))
    block_e: int = dataclasses.field(metadata=dict(static=True))
    n_tiles: int = dataclasses.field(metadata=dict(static=True))
    n_edges: int = dataclasses.field(metadata=dict(static=True))  # layout domain (0 = no layout)
    cache_key: str = dataclasses.field(metadata=dict(static=True))
    n_windows: int = dataclasses.field(default=0, metadata=dict(static=True))  # batched sweep width (0 = single window)
    ring_capacity: int = dataclasses.field(default=0, metadata=dict(static=True))  # ring-view slot count (0 = derive)
    batch_sig: str = dataclasses.field(default="", metadata=dict(static=True))  # QueryBatch shape signature ("" = not a batch plan)
    # Mesh axis name the edge axis of every view passed under this plan is
    # sharded over (None = edges replicated/local).  Set ONLY at trace time
    # inside an edge-sharded shard_map body (dataclasses.replace): every
    # segment combine then finishes with a psum/pmin/pmax over this axis.
    # Static, so edge-sharded and local traces can never alias a jit cache
    # entry even when their local avals coincide.
    edge_axis: Optional[str] = dataclasses.field(default=None, metadata=dict(static=True))
    # History tier of the planned window against a ColdStore's hot horizon
    # (DESIGN.md §7.8): "hot" (the ring serves it), "cold" (entirely below
    # the horizon — stitched from compacted chunks) or "split" (cold prefix
    # + hot suffix in one stitched view).  Static and on the cache key, so
    # a tier switch can NEVER alias a hot chain's jit cache — it falls
    # cold without consuming the donated state.
    tier: str = dataclasses.field(default="hot", metadata=dict(static=True))
    # Frontier-rung ladder cap (DESIGN.md §7.9): 0 disables; a positive
    # value is the largest frontier occupancy (vertex rung) the sparse
    # segments of a laddered fixpoint will serve — host-level solves under
    # this plan descend to frontier-proportional rounds once the live
    # frontier fits.  Static and on the cache key: laddered and dense
    # programs never alias a jit cache entry, and the fused serving step
    # (which traces the solves) keeps its dense one-dispatch contract —
    # the ladder only engages on host-level (concrete-array) calls.
    ladder: int = dataclasses.field(default=0, metadata=dict(static=True))

    @property
    def view_budget(self) -> int:
        """The budget the edge-view builder needs for this method."""
        return self.per_vertex_budget if self.method == "hybrid" else self.budget


def _cache_key(method: str, backend: str, budget: int, pvb: int,
               exchange: int, tile_v: int, block_e: int,
               n_windows: int = 0, ring_capacity: int = 0,
               batch_sig: str = "", tier: str = "hot",
               ladder: int = 0) -> str:
    key = f"{method}/{backend}/b{budget}/pv{pvb}/x{exchange}/t{tile_v}x{block_e}"
    if ring_capacity:
        key += f"/r{ring_capacity}"
    if n_windows:
        key += f"/w{n_windows}"
    if batch_sig:
        key += f"/q{batch_sig}"
    if tier != "hot":
        key += f"/T{tier}"
    if ladder:
        key += f"/L{ladder}"
    return key


def rung(n: int) -> int:
    """The static-shape budget ladder: round up to a power of two (one jit
    compilation per rung — DESIGN.md §2)."""
    n = max(int(n), 1)
    return 1 << (n - 1).bit_length()


def _empty_i32() -> jax.Array:
    # NB: never cached — a zero-size constant minted inside a jit trace is a
    # tracer, and holding it across traces leaks it.
    return jnp.zeros((0,), jnp.int32)


def make_plan(
    method: str = "scan",
    backend: str = "xla_segment",
    *,
    budget: int = 0,
    per_vertex_budget: int = 0,
    exchange_budget: int = 0,
    layout=None,
    n_edges: int = 0,
    tile_v: int = DEFAULT_TILE_V,
    block_e: int = DEFAULT_BLOCK_E,
    n_windows: int = 0,
    ring_capacity: int = 0,
    batch_sig: str = "",
    tier: str = "hot",
    ladder: int = 0,
) -> AccessPlan:
    """Direct plan constructor (the planner-free path: legacy shims, the
    distributed engine's per-shard plans, tests)."""
    if method not in METHODS:
        raise ValueError(f"method must be one of {METHODS}, got {method!r}")
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
    if tier not in TIERS:
        raise ValueError(f"tier must be one of {TIERS}, got {tier!r}")
    if ladder < 0:
        raise ValueError(f"ladder must be >= 0, got {ladder}")
    if layout is not None:
        perm = jnp.asarray(layout.perm)
        block_tile = jnp.asarray(layout.block_tile)
        tile_v, block_e, n_tiles = layout.tile_v, layout.block_e, layout.n_tiles
    else:
        perm = _empty_i32()
        block_tile = _empty_i32()
        n_tiles = 0
        if backend == "pallas_tiled":
            raise ValueError("pallas_tiled backend requires a TileLayout")
    return AccessPlan(
        layout_perm=perm,
        layout_block_tile=block_tile,
        method=method,
        backend=backend,
        budget=int(budget),
        per_vertex_budget=int(per_vertex_budget),
        exchange_budget=int(exchange_budget),
        tile_v=int(tile_v),
        block_e=int(block_e),
        n_tiles=int(n_tiles),
        n_edges=int(n_edges),
        cache_key=_cache_key(method, backend, int(budget), int(per_vertex_budget),
                             int(exchange_budget), int(tile_v), int(block_e),
                             int(n_windows), int(ring_capacity),
                             str(batch_sig), str(tier), int(ladder)),
        n_windows=int(n_windows),
        ring_capacity=int(ring_capacity),
        batch_sig=str(batch_sig),
        tier=str(tier),
        ladder=int(ladder),
    )


# identity-cached composite-key array per_vertex_window_budget bisects: the
# O(E_heavy) key build depends only on (graph, index), while the
# incremental server re-evaluates the budget on hybrid advances — pay the
# build once per TGER, each query is then one 2H searchsorted.
@identity_cache(8)
def _pvb_keys(t_start, out_offsets, indexed_ids):
    ts = np.asarray(t_start).astype(np.int64)
    off = np.asarray(out_offsets).astype(np.int64)
    hv = np.asarray(indexed_ids)
    hv = hv[hv >= 0].astype(np.int64)
    if hv.size == 0:
        return None
    lo, hi = off[hv], off[hv + 1]
    lens = hi - lo
    total = int(lens.sum())
    if total == 0:
        return None
    # flat edge positions of every heavy slice, slice-major
    starts = np.cumsum(lens) - lens
    flat = np.repeat(lo - starts, lens) + np.arange(total)
    rank = np.repeat(np.arange(hv.size, dtype=np.int64), lens)
    base = np.int64(np.iinfo(np.int32).min)
    keys = (rank << 33) + (ts[flat] - base)
    slots = np.arange(hv.size, dtype=np.int64) << 33
    return (keys, slots, base, hv.size)


def per_vertex_window_budget(
    g: TemporalGraph,
    idx: TGERIndex,
    window: Tuple[int, int],
    floor: int = 16,
) -> int:
    """Static per-vertex budget for the hybrid view: the max in-window
    start-count over indexed vertices, rounded to a power of two.
    Guarantees hybrid_view completeness for this window.

    Exact and fully vectorized: each indexed vertex's T-CSR slice is
    start-sorted, so slices concatenate into one globally sorted array of
    composite keys (slot << 33 | t_start - INT32_MIN) — built once per
    (graph, TGER) identity — and all 2H window bounds resolve in a single
    batched ``np.searchsorted``, O(H log E_heavy) per query.
    """
    if idx.n_indexed == 0:
        return floor
    entry = _pvb_keys(g.t_start, g.out_offsets, idx.indexed_ids)
    if entry is None:
        worst = floor
    else:
        keys, slots, base, n_hv = entry
        ws, we = int(window[0]), int(window[1])
        queries = np.concatenate([slots + (ws - base), slots + (we + 1 - base)])
        bounds = np.searchsorted(keys, queries, side="left")
        counts = bounds[n_hv:] - bounds[:n_hv]
        worst = max(floor, int(counts.max()))
    return 1 << (worst - 1).bit_length() if worst > 1 else 1


def heavy_window_budget(
    g: TemporalGraph,
    idx: TGERIndex,
    window: Tuple[int, int],
    floor: int = 16,
) -> int:
    """Ring-capacity rung for the HYBRID ring view (DESIGN.md §7.3): the
    count of heavy (indexed-source) edges whose start lies in the window,
    rounded to a power of two.  Unlike ``per_vertex_window_budget`` (a
    per-vertex max, which over-allocates H x budget slots), this is the
    exact total the positional heavy ring holds.  Monotone in window
    inclusion, so the union window's rung covers every member window."""
    from repro.core.tger import heavy_window_positions_host

    lo, hi = heavy_window_positions_host(idx, (int(window[0]), int(window[1])))
    return rung(max(hi - lo, floor))


# identity-cached tile layout: depends only on (dst array, sizes, tile
# shape) and is O(E log E) host work — build once per graph, not once per
# plan_query call.
@identity_cache(16)
def _layout_cached(dst, n_edges: int, n_vertices: int, tile_v: int,
                   block_e: int):
    from repro.kernels.layout import build_tile_layout

    layout = build_tile_layout(np.asarray(dst), n_vertices, tile_v, block_e)
    # device-put the layout arrays once; make_plan's jnp.asarray is then a
    # no-op and every plan for this graph shares the same buffers.
    return dataclasses.replace(
        layout, perm=jnp.asarray(layout.perm),
        block_tile=jnp.asarray(layout.block_tile),
    )


def _layout_for(g: TemporalGraph, tile_v: int, block_e: int):
    return _layout_cached(g.dst, int(g.n_edges), int(g.n_vertices),
                          int(tile_v), int(block_e))


def plan_query(
    g: TemporalGraph,
    tger: Optional[TGERIndex],
    window=None,
    *,
    windows=None,
    model: CostModel = CostModel(),
    access: str = "auto",
    backend: str = "xla_segment",
    exchange_budget: int = 0,
    hybrid_floor: int = 16,
    tile_v: int = DEFAULT_TILE_V,
    block_e: int = DEFAULT_BLOCK_E,
    coldstore=None,
    tier: Optional[str] = None,
    ladder: int = 0,
) -> AccessPlan:
    """THE planner: one host-side decision per algorithm run (the window is
    constant across rounds, so one plan serves every round).

    ``access``:
      * ``"auto"`` — paper Eq. 3 at call granularity via the SAT histogram
        estimate (scan vs index; hybrid is opt-in because its win is the
        skewed-hub regime the caller knows about);
      * ``"scan"`` / ``"index"`` / ``"hybrid"`` — forced.

    ``backend`` selects execution: ``xla_segment`` (masked segment-reduce)
    or ``pallas_tiled`` (destination-tile fused kernels; requires the scan
    method because the tile layout is a per-graph static grouping — the
    planner falls back to xla_segment otherwise, recorded in the plan).

    ``windows=[(t0, t1), ...]`` plans a **batched multi-window sweep**
    (DESIGN.md §6): one plan over the union window whose budgets are the
    max over the union's and every member window's budget rung, so the one
    gathered union edge set covers each window and the batched [W, V]
    execution is row-equivalent to W independent single-window runs.  The
    plan records ``n_windows`` so jitted sweeps specialize per W; the
    auto/forced access decision is made on the union window (the quantity
    the single shared traversal actually pays for).

    ``coldstore`` (a :class:`~repro.core.coldstore.ColdStore`) classifies
    the union window against the compacted-history horizon (DESIGN.md
    §7.8): a window at or above the store's watermark plans ``tier="hot"``
    as before; one entirely below plans ``tier="cold"``, one straddling
    ``tier="split"`` — both force the index method with the capacity rung
    taken from the EXACT position span, so the stitched view always
    covers.  ``tier=`` overrides the classification (the serving engine
    passes the tier it computed against its own carried ring's horizon).
    The tier is static on the plan signature: switching tiers can never
    alias a hot chain's jit cache.
    """
    if access not in ("auto",) + METHODS:
        raise ValueError(f"access must be auto|{'|'.join(METHODS)}, got {access!r}")
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")

    n_windows = 0
    if windows is not None:
        if window is not None:
            raise ValueError(
                "pass either window=... or windows=[...], not both "
                "(a single window is not implicitly added to the batch)"
            )
        wins = [(int(w[0]), int(w[1])) for w in windows]
        if not wins:
            raise ValueError("windows must be a non-empty sequence of (t0, t1)")
        n_windows = len(wins)
        win = (min(w[0] for w in wins), max(w[1] for w in wins))  # union
        member_wins = wins
    else:
        if window is None:
            raise ValueError("plan_query needs window=... or windows=[...]")
        win = (int(window[0]), int(window[1]))
        member_wins = []
    n_edges = g.n_edges

    budget = 0
    per_vertex = 0
    ring_capacity = 0
    if tger is None:
        method = "scan"
        if access in ("index", "hybrid"):
            raise ValueError(f"access={access!r} requires a TGER index")
    elif access == "hybrid":
        method = "hybrid"
        per_vertex = per_vertex_window_budget(g, tger, win, floor=hybrid_floor)
        # the union count dominates every member window's count (window
        # inclusion), but take the explicit max so the plan invariant
        # "union budget >= each per-window budget" holds by construction.
        for w in member_wins:
            per_vertex = max(
                per_vertex, per_vertex_window_budget(g, tger, w, floor=hybrid_floor)
            )
        # hybrid ring capacity: the heavy in-window COUNT rung (the count is
        # monotone in window inclusion, so the union rung covers members).
        ring_capacity = heavy_window_budget(g, tger, win, floor=hybrid_floor)
    else:
        dec = decide_access(
            tger, n_edges, win, model,
            force=None if access == "auto" else access,
        )
        method = dec.method
        if method == "index":
            # per-window budget ladder: the union gather must cover every
            # member window, so the plan's rung is the max over the union's
            # and each window's own rung.
            budget = dec.budget
            for w in member_wins:
                wdec = decide_access(tger, n_edges, w, model, force="index")
                budget = max(budget, wdec.budget)
            # coverage floor: the decision budget is histogram-ESTIMATED
            # (slack-padded, but an estimate); the exact union position
            # span is one cached searchsorted pair, so take the max — a
            # serving-horizon guard downstream may now treat an
            # under-capacity view as an error, never a silent truncation.
            p_lo, p_hi = window_positions_host(tger, win)
            budget = max(budget, rung(max(p_hi - p_lo, 1)))
            # index ring capacity IS the budget rung: the ring holds the
            # same [lo, lo+budget) positional range the cold view gathers.
            ring_capacity = budget

    # ---- history-tier classification (DESIGN.md §7.8) ----------------------
    if tier is None:
        tier = "hot"
        if (coldstore is not None and tger is not None
                and access in ("auto", "index")):
            tier = coldstore.classify(win)
    if tier not in TIERS:
        raise ValueError(f"tier must be one of {TIERS}, got {tier!r}")
    if tier != "hot":
        if tger is None:
            raise ValueError("tier planning requires a TGER index")
        if access not in ("auto", "index"):
            raise ValueError(
                f"tier={tier!r} (below-horizon) windows require the index "
                f"method — the cold store stitches a classic index ring "
                f"view; got access={access!r}")
        p_lo, p_hi = window_positions_host(tger, win)
        method = "index"
        budget = max(budget, rung(max(p_hi - p_lo, 16)))
        ring_capacity = budget

    if backend == "pallas_tiled" and method != "scan":
        backend = "xla_segment"  # tile layout is per-graph static: scan only

    layout = _layout_for(g, tile_v, block_e) if backend == "pallas_tiled" else None
    return make_plan(
        method, backend,
        budget=budget, per_vertex_budget=per_vertex,
        exchange_budget=int(exchange_budget),
        layout=layout, n_edges=n_edges if layout is not None else 0,
        tile_v=tile_v, block_e=block_e,
        n_windows=n_windows, ring_capacity=ring_capacity,
        tier=tier, ladder=int(ladder),
    )


def plan_batch(
    g: TemporalGraph,
    tger: Optional[TGERIndex],
    batch,
    *,
    model: CostModel = CostModel(),
    access: str = "auto",
    backend: str = "xla_segment",
    shards=None,
    bucketed: bool = False,
    **kw,
) -> AccessPlan:
    """Plan ONE union AccessPlan for a whole :class:`~repro.engine.queries.
    QueryBatch` (DESIGN.md §7.4): every (algorithm × source × window) row
    of the batch executes over the same gathered union view, so the plan
    is ``plan_query`` over the batch's distinct windows — budgets cover the
    union and every member window — with the batch's SHAPE signature
    riding the cache key (``AccessPlan.batch_sig``).  The signature keys
    group structure and row counts, never sources or window bounds, so a
    shape-stable tenant stream reuses one plan (and hence one fused-step
    jit entry) across its whole serving horizon.

    ``shards`` (the query-mesh device count, DESIGN.md §7.5) rides the
    signature too: the sharded fused step pads each group's row axis to a
    per-device capacity derived from the shard count, so a plan made for
    one mesh shape must not silently satisfy a state carried under
    another — switching mesh shape falls cold instead of mis-aliasing the
    jit cache.  An int is a 1-D query mesh (``@qD``); an ``(E, D)`` tuple
    is the 2-D edge×query mesh (``@eEqD``, DESIGN.md §7.7).  A tuple with
    E == 1 normalizes to the 1-D form — a (1, D) mesh runs the exact 1-D
    program, so it must share its cache key.

    ``bucketed`` keys the signature on the BUCKETED per-group row
    capacities (the admission ladder of DESIGN.md §7.6) instead of exact
    counts, so tenant churn inside a bucket replans to the same cache
    key."""
    plan = plan_query(
        g, tger, windows=batch.windows(), model=model, access=access,
        backend=backend, **kw,
    )
    sig = batch.signature(bucketed=bucketed)
    if shards is not None:
        if isinstance(shards, (tuple, list)):
            e, d = (int(shards[0]), int(shards[1]))
            sig += f"@q{d}" if e <= 1 else f"@e{e}q{d}"
        else:
            sig += f"@q{int(shards)}"
    return dataclasses.replace(
        plan,
        batch_sig=sig,
        cache_key=_cache_key(
            plan.method, plan.backend, plan.budget, plan.per_vertex_budget,
            plan.exchange_budget, plan.tile_v, plan.block_e, plan.n_windows,
            plan.ring_capacity, sig, plan.tier, plan.ladder),
    )


def decision_for(
    g: TemporalGraph,
    tger: Optional[TGERIndex],
    window,
    model: CostModel = CostModel(),
    force: Optional[str] = None,
) -> AccessDecision:
    """Diagnostic view of the planner's scan-vs-index decision (the legacy
    ``AccessDecision`` record, kept for benchmarks and the examples)."""
    if tger is None:
        return AccessDecision("scan", 0, float(g.n_edges), 1.0, 0.0, 0.0)
    return decide_access(
        tger, g.n_edges, (int(window[0]), int(window[1])), model, force=force
    )


__all__ = [
    "AccessPlan",
    "make_plan",
    "plan_query",
    "plan_batch",
    "decision_for",
    "per_vertex_window_budget",
    "heavy_window_budget",
    "rung",
    "METHODS",
    "BACKENDS",
    "TIERS",
]
