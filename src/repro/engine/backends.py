"""ExecutionBackend: how a plan's combines actually execute (DESIGN.md §1).

Two implementations of the one protocol:

  * ``xla_segment``  — masked ``jax.ops.segment_{min,max,sum}`` (XLA lowers
    these to scatter; fine on CPU/GPU, serializing on TPU);
  * ``pallas_tiled`` — destination-tile fused kernels
    (kernels/layout.py + kernels/temporal_edgemap.py + kernels/segment_spmm.py):
    the scatter becomes a VMEM-local compare-select tree (min) or a one-hot
    MXU matmul (sum).  Runs in interpret mode on CPU; ``interpret=False``
    on TPU.

The pallas backend accelerates what the tile layout covers — int32 min and
f32 sum combines over the graph's native destination order (scan method,
out direction).  Everything else transparently falls back to xla_segment,
so a plan's backend choice is a performance hint, never a correctness
constraint.
"""
from __future__ import annotations

from typing import Optional, Protocol

import jax
import jax.numpy as jnp

from repro.engine.plan import AccessPlan

INT_INF = jnp.iinfo(jnp.int32).max


def _identity(combine: str, dtype) -> jax.Array:
    if combine == "min":
        return jnp.array(INT_INF if jnp.issubdtype(dtype, jnp.integer) else jnp.inf, dtype)
    if combine == "max":
        return jnp.array(
            jnp.iinfo(jnp.int32).min if jnp.issubdtype(dtype, jnp.integer) else -jnp.inf,
            dtype,
        )
    if combine == "sum":
        return jnp.array(0, dtype)
    raise ValueError(combine)


def segment_combine(values, segment_ids, num_segments: int, combine: str,
                    mask=None, axis=None):
    """Masked segment-reduce; invalid lanes contribute the identity.

    ``axis`` names a mesh axis the EDGE axis of ``values``/``segment_ids``
    is sharded over (DESIGN.md §7.7): each device reduces its local edge
    chunk into a full [num_segments] partial, then one ``pmin/pmax/psum``
    over the axis combines the partials — min/max/sum are associative and
    identity-padded, so the sharded result equals the unsharded one (sum
    up to f32 reduction order).  ``axis=None`` is the plain local reduce."""
    ident = _identity(combine, values.dtype)
    if mask is not None:
        m = mask
        while m.ndim < values.ndim:
            m = m[..., None]
        values = jnp.where(m, values, ident)
        # route invalid lanes to segment 0 (still identity-valued, harmless)
        segment_ids = jnp.where(mask, segment_ids, 0)
    fn = dict(
        min=jax.ops.segment_min, max=jax.ops.segment_max, sum=jax.ops.segment_sum
    )[combine]
    # segment_min/max fill empty segments with the dtype's max/min (the
    # identity), segment_sum with 0 — identity semantics hold without fixup.
    out = fn(values, segment_ids, num_segments=num_segments)
    if axis is not None:
        coll = dict(min=jax.lax.pmin, max=jax.lax.pmax, sum=jax.lax.psum)[combine]
        out = coll(out, axis_name=axis)
    return out


def segment_combine_windows(values, segment_ids, num_segments: int,
                            combine: str, masks=None, axis=None):
    """Batched masked segment-reduce over a shared edge set (DESIGN.md §6):
    ``values`` is [W, K, ...] (one candidate row per query window), ``masks``
    [W, K]; ``segment_ids`` [K] is shared across windows.  Returns
    [W, num_segments, ...] — W reductions over ONE gathered edge set.
    ``axis`` as in :func:`segment_combine`: one cross-edge-shard collective
    per call, applied to the whole [W, num_segments] partial at once."""
    if masks is None:
        out = jax.vmap(
            lambda v: segment_combine(v, segment_ids, num_segments, combine)
        )(values)
    else:
        out = jax.vmap(
            lambda v, m: segment_combine(v, segment_ids, num_segments, combine,
                                         mask=m)
        )(values, masks)
    if axis is not None:
        coll = dict(min=jax.lax.pmin, max=jax.lax.pmax, sum=jax.lax.psum)[combine]
        out = coll(out, axis_name=axis)
    return out


class ExecutionBackend(Protocol):
    """Backend protocol: execute a (masked) segment combine, single-window
    or batched over a window axis sharing one edge set."""

    name: str

    def combine(self, plan: Optional[AccessPlan], values, segment_ids,
                num_segments: int, op: str, mask=None):
        ...

    def combine_windows(self, plan: Optional[AccessPlan], values, segment_ids,
                        num_segments: int, op: str, masks=None):
        ...


class XlaSegmentBackend:
    """Today's masked segment-reduce, unchanged."""

    name = "xla_segment"

    def combine(self, plan, values, segment_ids, num_segments, op, mask=None):
        del plan
        return segment_combine(values, segment_ids, num_segments, op, mask=mask)

    def combine_windows(self, plan, values, segment_ids, num_segments, op,
                        masks=None):
        del plan
        return segment_combine_windows(values, segment_ids, num_segments, op,
                                       masks=masks)


class PallasTiledBackend:
    """Destination-tile fused kernels, selected by the plan's layout.

    ``combine`` expects ``segment_ids`` in the same edge order the layout
    was built from (the graph's native order; callers gate on that).
    """

    name = "pallas_tiled"

    def __init__(self, interpret: bool = True):
        self.interpret = interpret

    # -- eligibility (static, trace-time) -----------------------------------
    def _supports(self, plan, values, num_segments, op) -> bool:
        if plan is None or plan.layout_perm.shape[0] == 0:
            return False
        if plan.n_edges and values.shape[0] != plan.n_edges:
            return False
        if num_segments > plan.n_tiles * plan.tile_v:
            return False
        if op == "min":
            return values.ndim == 1 and values.dtype == jnp.int32
        if op == "sum":
            return (
                values.ndim in (1, 2)
                and jnp.issubdtype(values.dtype, jnp.floating)
            )
        return False

    def _gathered(self, plan, segment_ids):
        perm = plan.layout_perm
        safe = jnp.maximum(perm, 0)
        seg_g = jnp.where(perm >= 0, jnp.asarray(segment_ids)[safe], 0)
        dst_local = seg_g - (seg_g // plan.tile_v) * plan.tile_v
        return safe, perm >= 0, dst_local

    def combine(self, plan, values, segment_ids, num_segments, op, mask=None):
        if not self._supports(plan, values, num_segments, op):
            return segment_combine(values, segment_ids, num_segments, op, mask=mask)
        if op == "min":
            return self._combine_min(plan, values, segment_ids, num_segments, mask)
        return self._combine_sum(plan, values, segment_ids, num_segments, mask)

    def combine_windows(self, plan, values, segment_ids, num_segments, op,
                        masks=None):
        """Batched combine over a window axis: the layout gather happens once,
        then the tiled kernel runs per window under ``lax.map`` (one trace,
        W sequential kernel launches — the kernel itself is not re-batched)."""
        if not self._supports(plan, values[0], num_segments, op):
            return segment_combine_windows(values, segment_ids, num_segments,
                                           op, masks=masks)
        if op == "min":
            return self._combine_min_windows(
                plan, values, segment_ids, num_segments, masks)
        return self._combine_sum_windows(
            plan, values, segment_ids, num_segments, masks)

    def _combine_min(self, plan, values, segment_ids, num_segments, mask):
        from repro.kernels.temporal_edgemap import segment_min_tiles

        cand = values if mask is None else jnp.where(mask, values, INT_INF)
        safe, in_perm, dst_local = self._gathered(plan, segment_ids)
        cand_g = jnp.where(in_perm, cand[safe], INT_INF)
        tiles = segment_min_tiles(
            dst_local, cand_g, plan.layout_block_tile, plan.n_tiles,
            tile_v=plan.tile_v, block_e=plan.block_e,
            interpret=self.interpret,
        )
        return tiles.reshape(-1)[:num_segments]

    def _combine_sum(self, plan, values, segment_ids, num_segments, mask):
        from repro.kernels.segment_spmm import segment_spmm_tiles

        squeeze = values.ndim == 1
        msgs = values[:, None] if squeeze else values
        safe, in_perm, dst_local = self._gathered(plan, segment_ids)
        msg_g = msgs[safe]
        valid = in_perm if mask is None else in_perm & mask[safe]
        tiles = segment_spmm_tiles(
            dst_local, msg_g, valid.astype(jnp.int32),
            plan.layout_block_tile, plan.n_tiles,
            tile_v=plan.tile_v, block_e=plan.block_e,
            interpret=self.interpret,
        )
        out = tiles.reshape(-1, msgs.shape[-1])[:num_segments]
        return out[:, 0] if squeeze else out

    # -- batched-window variants (shared layout gather, per-window kernel) ---
    def _combine_min_windows(self, plan, values, segment_ids, num_segments,
                             masks):
        from repro.kernels.temporal_edgemap import segment_min_tiles

        cand = values if masks is None else jnp.where(masks, values, INT_INF)
        safe, in_perm, dst_local = self._gathered(plan, segment_ids)
        cand_g = jnp.where(in_perm[None, :], cand[:, safe], INT_INF)  # [W, Ep]

        def one(c):
            tiles = segment_min_tiles(
                dst_local, c, plan.layout_block_tile, plan.n_tiles,
                tile_v=plan.tile_v, block_e=plan.block_e,
                interpret=self.interpret,
            )
            return tiles.reshape(-1)[:num_segments]

        return jax.lax.map(one, cand_g)

    def _combine_sum_windows(self, plan, values, segment_ids, num_segments,
                             masks):
        from repro.kernels.segment_spmm import segment_spmm_tiles

        squeeze = values.ndim == 2
        msgs = values[..., None] if squeeze else values      # [W, K, F]
        safe, in_perm, dst_local = self._gathered(plan, segment_ids)
        msg_g = msgs[:, safe, :]                             # [W, Ep, F]
        if masks is None:
            valid = jnp.broadcast_to(in_perm, (msgs.shape[0], in_perm.shape[0]))
        else:
            valid = in_perm[None, :] & masks[:, safe]

        def one(args):
            m, v = args
            tiles = segment_spmm_tiles(
                dst_local, m, v.astype(jnp.int32),
                plan.layout_block_tile, plan.n_tiles,
                tile_v=plan.tile_v, block_e=plan.block_e,
                interpret=self.interpret,
            )
            return tiles.reshape(-1, m.shape[-1])[:num_segments]

        out = jax.lax.map(one, (msg_g, valid))
        return out[..., 0] if squeeze else out


_BACKENDS = {
    "xla_segment": XlaSegmentBackend(),
    "pallas_tiled": PallasTiledBackend(interpret=True),
}


def get_backend(name: str) -> ExecutionBackend:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; have {sorted(_BACKENDS)}")


def combine_for_plan(
    plan: Optional[AccessPlan],
    values,
    segment_ids,
    num_segments: int,
    op: str,
    mask=None,
    *,
    use_layout: bool = False,
):
    """Plan-directed combine.  ``use_layout=True`` asserts the caller's
    ``segment_ids`` are in the edge order the plan's layout was built from
    (scan view, reduce-into-destination); only then may the tiled kernels
    run.  All other combines take the xla path.  A plan carrying
    ``edge_axis`` (an edge-sharded shard_map body, DESIGN.md §7.7) always
    takes the segment path — the tile layout is a whole-graph static
    grouping that does not partition along the ring shards — and finishes
    with the one cross-shard collective."""
    axis = None if plan is None else plan.edge_axis
    if (plan is not None and use_layout and axis is None
            and plan.backend == "pallas_tiled"):
        return get_backend("pallas_tiled").combine(
            plan, values, segment_ids, num_segments, op, mask=mask
        )
    return segment_combine(values, segment_ids, num_segments, op, mask=mask,
                           axis=axis)


def combine_windows_for_plan(
    plan: Optional[AccessPlan],
    values,           # [W, K, ...]
    segment_ids,      # [K] shared across windows
    num_segments: int,
    op: str,
    masks=None,       # [W, K]
    *,
    use_layout: bool = False,
):
    """Batched plan-directed combine (DESIGN.md §6): W per-window reductions
    over ONE shared candidate edge set, returning [W, num_segments, ...].
    Same layout-eligibility (and ``edge_axis``) contract as
    :func:`combine_for_plan`."""
    axis = None if plan is None else plan.edge_axis
    if (plan is not None and use_layout and axis is None
            and plan.backend == "pallas_tiled"):
        return get_backend("pallas_tiled").combine_windows(
            plan, values, segment_ids, num_segments, op, masks=masks
        )
    return segment_combine_windows(values, segment_ids, num_segments, op,
                                   masks=masks, axis=axis)


__all__ = [
    "ExecutionBackend",
    "XlaSegmentBackend",
    "PallasTiledBackend",
    "segment_combine",
    "segment_combine_windows",
    "get_backend",
    "combine_for_plan",
    "combine_windows_for_plan",
]
