"""Quickstart: build a temporal graph, index it, run temporal analytics.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import build_tger, decision_for
from repro.core.algorithms import (
    earliest_arrival,
    temporal_cc,
    temporal_pagerank,
)
from repro.core.temporal_graph import from_edges


def main():
    # A small contact network: (who, whom, interval-start, interval-end)
    #   a=0 b=1 c=2 d=3 e=4 f=5 g=6  (cf. the paper's Figure 1)
    edges = [
        (0, 1, 1, 2), (1, 2, 3, 4), (2, 3, 5, 6),
        (0, 4, 2, 3), (4, 3, 4, 7), (3, 5, 8, 9),
        (5, 6, 10, 11), (1, 6, 2, 12),
    ]
    src, dst, ts, te = map(np.asarray, zip(*edges))
    g = from_edges(src, dst, ts, te)
    print(f"graph: {g.n_vertices} vertices, {g.n_edges} temporal edges")

    # TGER: time-first index + per-vertex histograms (selective: small cutoff
    # here so the demo actually indexes something)
    idx = build_tger(g, degree_cutoff=2)
    print(f"TGER built: {idx.n_indexed} vertices indexed")

    # cost-model access plan for a query window
    window = (0, 12)
    dec = decision_for(g, idx, window)
    print(f"window {window}: access={dec.method} "
          f"(selectivity {dec.selectivity:.2f}, budget {dec.budget})")

    # earliest arrival from vertex a (Algorithm 2)
    arr = np.asarray(earliest_arrival(g, 0, window))
    for v, t in enumerate(arr):
        label = chr(ord("a") + v)
        print(f"  earliest arrival a -> {label}: "
              f"{'unreachable' if t == np.iinfo(np.int32).max else t}")

    labels = np.asarray(temporal_cc(g, window))
    print("temporal components:", labels.tolist())

    pr = np.asarray(temporal_pagerank(g, window, n_iters=50))
    print("top vertex by temporal PageRank:", chr(ord("a") + int(pr.argmax())))


if __name__ == "__main__":
    main()
