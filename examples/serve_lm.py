"""Batched serving example: continuous batching with the ServeEngine.

  PYTHONPATH=src python examples/serve_lm.py
"""
import sys

sys.path.insert(0, "src")

from repro.launch import serve as serve_mod


def main():
    sys.argv = ["serve", "--arch", "smollm-135m", "--requests", "12",
                "--slots", "4", "--max-new", "10"]
    serve_mod.main()


if __name__ == "__main__":
    main()
