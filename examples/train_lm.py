"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
the synthetic Markov corpus, with checkpointing and straggler monitoring.

Defaults are sized for this CPU container (~135M-param smollm config with a
reduced width); pass --full for the real smollm-135m at 30 layers.

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses
import sys

import jax

sys.path.insert(0, "src")

from repro.configs import get_arch
from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="train the real 135M config (slow on CPU)")
    args = ap.parse_args()

    argv = [
        "--arch", "smollm-135m",
        "--scale", "full" if args.full else "smoke",
        "--steps", str(args.steps),
        "--batch", "16", "--seq", "64", "--lr", "3e-3",
        "--ckpt", "/tmp/repro_train_lm", "--ckpt-every", "100",
    ]
    sys.argv = ["train"] + argv
    losses = train_mod.main()
    assert losses[-1] < losses[0], "loss must decrease"
    print("OK: loss decreased", losses[0], "->", losses[-1])


if __name__ == "__main__":
    main()
