"""Multi-device temporal analytics: the paper's engine on a device mesh.

Runs the edge-partitioned EA engine (scan + selective paths) on 8 forced
host devices and verifies both match the single-device engine — the same
program the 512-chip dry-run compiles.

  PYTHONPATH=src python examples/distributed_analytics.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.algorithms import earliest_arrival
from repro.core.edgemap import INT_INF
from repro.data.generators import power_law_temporal_graph
from repro.distributed import graph_engine as ge


def main():
    from repro.distributed.compat import make_mesh
    mesh = make_mesh((4, 2), ("data", "model"))
    print("mesh:", dict(zip(mesh.axis_names, mesh.devices.shape)))
    g = power_law_temporal_graph(500, 20_000, seed=11)
    ts = np.asarray(g.t_start)
    win = jnp.asarray(
        [int(np.quantile(ts, 0.7)), int(np.asarray(g.t_end).max())], jnp.int32
    )
    sources = jnp.asarray([0, 1, 2, 3])
    arr0 = jnp.full((4, g.n_vertices), INT_INF, jnp.int32)
    arr0 = arr0.at[jnp.arange(4), sources].set(win[0])

    # scan path: edges sharded over data, sources over model
    edges = ge.shard_edges(mesh, g.src, g.dst, g.t_start, g.t_end)
    evalid = ge.shard_edges(mesh, jnp.ones(g.n_edges, bool))[0]
    out = ge.run_distributed_ea(mesh, arr0, edges, evalid, win, max_rounds=64)

    # selective path: per-shard time-first order + budget gather
    ssrc, sdst, sts, ste, svalid = ge.sort_edges_by_time_per_shard(
        mesh, g.src, g.dst, g.t_start, g.t_end
    )
    from repro.engine.plan import make_plan
    sel = jax.jit(ge.make_ea_round_plan(mesh, g.n_vertices,
                                        make_plan("index", budget=4096)))
    arr = arr0
    for _ in range(64):
        new = sel(arr, ssrc, sdst, sts, ste, svalid, win)
        if bool(jnp.all(new == arr)):
            break
        arr = new

    ref = np.stack(
        [np.asarray(earliest_arrival(g, int(s), (int(win[0]), int(win[1]))))
         for s in sources]
    )
    print("scan path == single-device:", bool((np.asarray(out) == ref).all()))
    print("selective path == single-device:", bool((np.asarray(arr) == ref).all()))
    reach = (ref[0] < INT_INF).sum()
    print(f"source {int(sources[0])}: {reach}/{g.n_vertices} reachable in window")


if __name__ == "__main__":
    main()
