"""Contact tracing over a temporal interaction graph (paper Table 1:
epidemiology / temporal minimal paths).

Builds a bursty synthetic contact network, finds everyone reachable from a
patient-zero within an exposure window (earliest arrival = earliest possible
infection time), ranks super-spreaders by temporal betweenness, and shows
the selective-indexing decision flipping between scan and TGER as the
window narrows.

  PYTHONPATH=src python examples/contact_tracing.py
"""
import numpy as np

from repro.core import build_tger, decision_for
from repro.engine import make_plan
from repro.core.algorithms import earliest_arrival, temporal_betweenness
from repro.core.selective import CostModel
from repro.data.generators import power_law_temporal_graph

INT_INF = np.iinfo(np.int32).max


def main():
    g = power_law_temporal_graph(2000, 60_000, seed=7)
    idx = build_tger(g, degree_cutoff=256)
    ts = np.asarray(g.t_start)
    t_max = int(np.asarray(g.t_end).max())
    patient_zero = int(np.argmax(np.asarray(g.out_degree)))
    print(f"contact network: {g.n_vertices} people, {g.n_edges} contacts, "
          f"{idx.n_indexed} hubs TGER-indexed; patient zero = {patient_zero}")

    for frac, label in [(1.0, "full history"), (0.05, "last 5% of time")]:
        lo = int(np.quantile(ts, 1 - frac))
        window = (lo, t_max)
        dec = decision_for(g, idx, window, CostModel())
        plan = make_plan(dec.method,
                         budget=dec.budget if dec.method == "index" else 0)
        arr = np.asarray(
            earliest_arrival(g, patient_zero, window, idx, plan=plan)
        )
        exposed = (arr < INT_INF).sum()
        print(f"[{label}] access={plan.method:5s} "
              f"(sel {dec.selectivity:.3f})  exposed={exposed} people")

    # super-spreader ranking over the recent window
    lo = int(np.quantile(ts, 0.8))
    sources = np.argsort(np.asarray(g.out_degree))[-4:].astype(np.int32)
    bc = np.asarray(temporal_betweenness(g, sources, (lo, t_max), n_buckets=64))
    top = np.argsort(bc)[-5:][::-1]
    print("top-5 temporal-betweenness hubs (recent window):")
    for v in top:
        print(f"  person {int(v):5d}  centrality {bc[v]:.1f}")


if __name__ == "__main__":
    main()
