#!/usr/bin/env bash
# Tier-1 verification: exactly the command ROADMAP.md pins, from any cwd.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
# CI marker: the long-horizon serving soak (tests/test_serving_soak.py)
# drops from 220 to 60 advances under CI to bound wall clock.  GitHub
# Actions sets CI=true already; export it here so local ci.sh runs match.
export CI="${CI:-1}"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

# smoke the perf trajectory: gather-once vs re-gather + FUSED incremental
# sweeps (one-dispatch advances asserted against the dispatch-site log,
# result-identity asserted before timing; emits BENCH_fixpoint.json at the
# repo root, including the tiny-budget crossover regime)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run --quick --only fixpoint
