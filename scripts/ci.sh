#!/usr/bin/env bash
# Tier-1 verification: exactly the command ROADMAP.md pins, from any cwd.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
# CI marker: the long-horizon serving soaks (tests/test_serving_soak.py:
# 220 -> 60 advances; tests/test_multitenant.py: 110 -> 36 advances) are
# reduced under CI to bound wall clock.  GitHub Actions sets CI=true
# already; export it here so local ci.sh runs match.
export CI="${CI:-1}"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

# smoke the perf trajectory: gather-once vs re-gather + FUSED incremental
# sweeps + the multi-tenant 1/4/16-tenant queries-per-second regime
# (one-dispatch advances asserted against the dispatch-site log at every
# batch size, result-identity asserted before timing; emits
# BENCH_fixpoint.json at the repo root, including the tiny-budget
# crossover regime)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run --quick --only fixpoint
