#!/usr/bin/env bash
# Tier-1 verification: exactly the command ROADMAP.md pins, from any cwd.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

# smoke the perf trajectory: gather-once vs re-gather + incremental sweeps
# (asserts result-identity internally; emits BENCH_fixpoint.json at the root)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run --quick --only fixpoint
