#!/usr/bin/env bash
# Tier-1 verification: exactly the command ROADMAP.md pins, from any cwd.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
# CI marker: the long-horizon serving soaks (tests/test_serving_soak.py:
# 220 -> 60 advances; tests/test_multitenant.py: 110 -> 36 advances;
# tests/test_daemon.py churn soak: 80 -> 24 ticks) are reduced under CI
# to bound wall clock.  GitHub Actions sets CI=true already; export it
# here so local ci.sh runs match.
export CI="${CI:-1}"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

# forced-multi-device leg: the query-mesh sharding paths (DESIGN.md §7.5)
# only exercise real device boundaries when XLA fakes >1 host device, so
# rerun the distributed + sharded-serving suites under a 4-device CPU
# backend.  The workflow matrix runs this script under both the jax 0.4.37
# floor and jax-latest, so the shard_map compat shims get both pins.
XLA_FLAGS="--xla_force_host_platform_device_count=4" \
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m pytest -x -q tests/test_distributed.py tests/test_sharded_serving.py

# forced-8-device leg: the edge×query 2-D meshes (DESIGN.md §7.7) at
# mesh shapes (2,4) and (4,2) — both axes genuinely multi-device, which
# the 4-device leg above (max (2,2)) cannot produce.  Reuses the
# env-parameterized 2-D soak with CI-reduced advances; runs on both jax
# matrix legs like the rest of this script.
SOAK2D_DEVICES=8 SOAK2D_MESHES="2x4,4x2" SOAK2D_STEPS=12 \
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m pytest -x -q tests/test_sharded_serving.py -k soak_2d

# the compaction soak at FULL length (DESIGN.md §7.8): tier-1 above runs
# tests/test_coldstore.py CI-reduced (COLD_SOAK=16); rerun the acceptance
# soak at the full 48 advances — one fused dispatch per advance, zero
# retraces after warmup, rows bit-identical to the compaction-off chain
# on EVERY advance, cold-store watermark tracking the ring's low
# watermark.  Runs on both legs of the jax version matrix.
COLD_SOAK=48 \
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m pytest -x -q tests/test_coldstore.py -k compaction_soak

# smoke the serving daemon end to end (DESIGN.md §7.6): a short tick loop
# with Poisson tenant churn, bucketed async admission and cost-class
# round-robin — the launch-path wiring the daemon soak in tier-1 above
# (tests/test_daemon.py, CI-reduced) does not cover.  Runs on both legs
# of the jax version matrix like everything else in this script.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m repro.launch.serve --graph --daemon --ticks 8 --tenants 8 \
  --n-vertices 500 --n-edges 10000

# smoke the perf trajectory: gather-once vs re-gather + FUSED incremental
# sweeps + the multi-tenant 1/4/16-tenant queries-per-second regime + the
# sharded qps-vs-device-count chain + the async-admission daemon part
# (bucketed-vs-naive admission cost and Poisson p50/p99 — one-dispatch
# advances asserted against the dispatch-site log at every batch size and
# device count, result-identity asserted before timing; emits
# BENCH_fixpoint.json at the repo root, including the tiny-budget
# crossover regime and the part-2b gate check: the stateless
# tiny_budget_gate chain must not regress below the cold baseline)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run --quick --only fixpoint

# smoke the tiered-history part (DESIGN.md §7.8) at reduced sizes: the
# 48-advance compaction-on/off lockstep (identity asserted before timing,
# one-dispatch + zero-retrace asserted per advance) and the time-travel
# stitch vs cold full-history rebuild — merges part 7 into
# BENCH_fixpoint.json; plus the history-chunks launch wiring, once
# in-memory and once spilling sealed chunk payloads to memmap files
# (DESIGN.md §7.9 satellite: decodes must stay bit-identical off disk).
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run --quick --only history
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m repro.launch.serve --graph --daemon --ticks 6 --tenants 6 \
  --n-vertices 500 --n-edges 10000 --history-chunks 512
SPILL_DIR="$(mktemp -d)"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
  python -m repro.launch.serve --graph --tenants 6 --advances 8 \
  --n-vertices 500 --n-edges 10000 --history-chunks 512 \
  --history-spill-dir "$SPILL_DIR"
rm -rf "$SPILL_DIR"

# smoke the frontier-rung ladder part (DESIGN.md §7.9) at reduced sizes:
# the deep-transit laddered-vs-dense EA rows (bit-identity asserted
# BEFORE timing, zero retraces on repeated same-shape laddered solves
# asserted from the trace log) and the honest shallow power-law
# crossover row, plus the part-2b tiny-budget gate assertion inside the
# fixpoint leg above — merges part 8 into BENCH_fixpoint.json.  Runs on
# both legs of the jax version matrix like everything else here.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run --quick --only frontier
