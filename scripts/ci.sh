#!/usr/bin/env bash
# Tier-1 verification: exactly the command ROADMAP.md pins, from any cwd.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
