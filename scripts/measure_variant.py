import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Measure a config-variant of an LM cell without changing defaults —
the §Perf iteration tool.

  PYTHONPATH=src python scripts/measure_variant.py \
      --arch qwen3-moe-30b-a3b --shape train_4k --set remat=False
"""
import argparse
import dataclasses
import json
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.configs import get_arch  # noqa: E402
from repro.launch.dryrun import parse_collectives  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--set", action="append", default=[],
                    help="cfg field overrides, e.g. remat=False q_chunk=256")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = {"True": True, "False": False}.get(v, None)
        if overrides[k] is None:
            try:
                overrides[k] = int(v)
            except ValueError:
                overrides[k] = v
    spec.cfg = dataclasses.replace(spec.cfg, **overrides)

    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    L = spec.layer_count()

    def measure(lowerable):
        fn, a, sh, d = lowerable
        c = jax.jit(fn, in_shardings=sh, donate_argnums=tuple(d)).lower(*a).compile()
        ca = c.cost_analysis() or {}
        colls = parse_collectives(c.as_text())
        ma = c.memory_analysis()
        return dict(
            flops=float(ca.get("flops", 0)),
            bytes=float(ca.get("bytes accessed", 0)),
            wire=sum(x["wire_bytes"] for x in colls),
            peak=int(ma.peak_memory_in_bytes),
        )

    full = measure(spec.lowerable(args.shape, mesh))
    p1 = measure(spec.layer_scaled_lowerable(args.shape, mesh, 1))
    p2 = measure(spec.layer_scaled_lowerable(args.shape, mesh, 2))
    extr = {k: p1[k] + (p2[k] - p1[k]) * (L - 1) for k in ("flops", "bytes", "wire")}
    rec = dict(
        arch=args.arch, shape=args.shape, mesh=args.mesh, overrides=overrides,
        peak_gib=full["peak"] / 2**30,
        flops_per_device=extr["flops"],
        bytes_per_device=extr["bytes"],
        wire_per_device=extr["wire"],
        t_compute_s=extr["flops"] / 197e12,
        t_memory_s=extr["bytes"] / 819e9,
        t_collective_s=extr["wire"] / 50e9,
    )
    print(json.dumps(rec, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
